"""repro.optim"""
