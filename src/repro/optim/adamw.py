"""AdamW with decoupled weight decay, global-norm gradient clipping and
pluggable LR schedules. Pure-pytree implementation (no optax dependency);
optimizer state mirrors the param tree so it inherits the param shardings
(ZeRO-1: states live sharded exactly like their FSDP-sharded params).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # decay mask: paths matching these substrings get no weight decay
    no_decay: tuple = ("ln", "norm", "bias", "b_if", "dt_b", "A_log",
                       "Dskip", "/g", "/b")
    # freeze mask: paths matching these substrings are passed through
    # BIT-IDENTICALLY (no fp32 round trip, no moment update) and excluded
    # from the global-norm clip. Used by cushioncache.prefix_tune to train
    # only the kv block of a mixed cushion artifact (hybrid recurrent
    # "state" leaves ride along untouched).
    frozen: tuple = ()

    def init(self, params: Any) -> AdamWState:
        z = lambda p: jax.tree_util.tree_map(
            lambda a: jnp.zeros_like(a, dtype=jnp.float32), p)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=z(params),
                          nu=z(params))

    def _decay_mask(self, params: Any) -> Any:
        from repro.distributed.sharding import tree_paths
        paths = tree_paths(params)
        return jax.tree_util.tree_map(
            lambda p: not any(s in p for s in self.no_decay), paths)

    def _frozen_mask(self, params: Any) -> Any:
        from repro.distributed.sharding import tree_paths
        paths = tree_paths(params)
        return jax.tree_util.tree_map(
            lambda p: any(s in p for s in self.frozen), paths)

    def update(self, grads: Any, state: AdamWState, params: Any):
        frozen = self._frozen_mask(params) if self.frozen else None
        if frozen is not None:
            # frozen leaves contribute nothing to the global norm (their
            # grads are typically exact zeros from stop_gradient anyway)
            grads = jax.tree_util.tree_map(
                lambda g, f: jnp.zeros_like(g) if f else g, grads, frozen)
        # global-norm clip
        if self.grad_clip > 0:
            gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                              for g in jax.tree_util.tree_leaves(grads)))
            scale = jnp.minimum(1.0, self.grad_clip / (gn + 1e-9))
            grads = jax.tree_util.tree_map(
                lambda g: (g.astype(jnp.float32) * scale), grads)
        else:
            gn = jnp.zeros(())
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32), grads)

        step = state.step + 1
        lr_t = self.lr(step)
        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)
        mask = self._decay_mask(params)

        def upd(g, m, v, p, do_decay):
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * jnp.square(g)
            mhat = m / b1c
            vhat = v / b2c
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if do_decay and self.weight_decay > 0:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m, v

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        flat_mask = treedef.flatten_up_to(mask)
        flat_fz = (treedef.flatten_up_to(frozen) if frozen is not None
                   else [False] * len(flat_p))
        new_p, new_m, new_v = [], [], []
        for g, m, v, p, dk, fz in zip(flat_g, flat_m, flat_v, flat_p,
                                      flat_mask, flat_fz):
            if fz:
                pn, mn, vn = p, m, v    # bit-identical passthrough
            else:
                pn, mn, vn = upd(g, m, v, p, dk)
            new_p.append(pn)
            new_m.append(mn)
            new_v.append(vn)
        unf = treedef.unflatten
        return unf(new_p), AdamWState(step=step, mu=unf(new_m),
                                      nu=unf(new_v)), {"grad_norm": gn,
                                                       "lr": lr_t}


def constant_lr(lr: float) -> Callable:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_lr(peak: float, warmup: int, total: int,
              floor: float = 0.1) -> Callable:
    def f(step):
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(1.0, warmup)
        prog = jnp.clip((s - warmup) / jnp.maximum(1.0, total - warmup), 0, 1)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return peak * jnp.where(s < warmup, warm, cos)
    return f
