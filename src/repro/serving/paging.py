"""Host-side page allocator for the paged continuous-batching KV pool.

The vLLM-style layout (serving/scheduler.py ``ContinuousEngine(paged=True)``)
replaces the dense per-slot rows ``(L, n_slots, max_seq, K, hd)`` with a flat
page store ``(L, n_pages, page_size, K, hd)`` plus a per-slot page table
``(n_slots, P)`` (``P = max_seq // page_size``) mapping *logical* page ``j``
of a slot — cache positions ``[j*ps, (j+1)*ps)`` — to a *physical* page.
Memory then scales with live tokens instead of ``n_slots * max_seq``.

This module is the bookkeeping half: pure numpy/host state, no jax. The
device half (the page store itself, the scatter of admission rows into
pages, the scalar-prefetched page-table reads inside the decode kernel)
lives in the scheduler and ``kernels/flash_decode.flash_decode_paged``.

Contract
--------
* Physical page 0 is a reserved scratch page: it is never handed out by the
  allocator and every unmapped table entry points at it. Dead rows with a
  frozen decode position keep writing there after their real pages are
  freed, and the kernel/oracle never *use* what they read from it (masked
  by ``pos`` / the cushion boundary), so its content is don't-care.
* The fp cushion block (positions ``[0:m)``) never occupies pages at all:
  it lives once, batch-free, in the pool-level ``kc``/``vc`` refs — the
  "one refcounted, read-only cushion page mapped into every slot". Logical
  pages entirely below the cushion stay mapped to scratch forever; the
  kernel masks ``kj >= m`` out of the page reads. ``cushion_refcount``
  counts the pool's own pinned reference plus one per live slot.
* Admission *reserves* every page the request can possibly need
  (``ceil((m + prompt + budget) / ps)`` worth), maps the prompt pages
  immediately (the admission scatter writes them), and leaves decode pages
  to be mapped on demand from the free list as the slot's position crosses
  page boundaries (``ensure_mapped``). Reservation makes mid-decode
  exhaustion impossible: ``available()`` subtracts outstanding
  reservations, so ``admit`` fails up front (backpressure) instead of the
  pool underflowing at step time.
* Prefix caching (fp pools only): full pages of cushion+prompt content are
  content-addressed by ``(logical page, prompt-stem bytes)``. A later
  request whose prompt shares the stem maps the donor's pages read-only
  (refcount++), and only its tail is prefilled. Pages are never written
  after their owner's admission (decode appends go to fresh pages), so
  "copy-on-write" degenerates to copy-never: divergence simply allocates a
  fresh page at the first non-matching logical index. The registry holds
  its own reference on each cached page; when the free list runs short the
  oldest unshared entries are evicted back to it.
"""
from __future__ import annotations

import collections
from typing import Dict, List, Optional, Tuple

import numpy as np


class PagePool:
    """Free-list page allocator with refcounts, reservations and an optional
    prefix-cache registry. All state is host-side; the scheduler mirrors
    ``table`` to the device after any mutation (``dirty`` tracks that)."""

    def __init__(self, n_slots: int, max_seq: int, page_size: int,
                 n_pages: int, cushion_m: int = 0,
                 prefix_cache: bool = False):
        if max_seq % page_size:
            raise ValueError(
                f"page_size {page_size} must divide max_seq {max_seq}")
        if n_pages < 2:
            raise ValueError("need at least one scratch + one content page")
        self.ps = page_size
        self.P = max_seq // page_size
        self.n_pages = n_pages
        self.m = cushion_m
        # first logical page holding content: pages fully below the cushion
        # are never allocated (their positions live in the kc/vc refs)
        self.c0 = cushion_m // page_size
        self.table = np.zeros((n_slots, self.P), np.int32)
        self.free: List[int] = list(range(n_pages - 1, 0, -1))  # LIFO stack
        self.refs = np.zeros((n_pages,), np.int32)
        self.refs[0] = 1                    # scratch page: pinned forever
        self.reserved = 0                   # promised to live slots, unmapped
        self._slot_reserved = np.zeros((n_slots,), np.int64)
        self._slot_next = np.zeros((n_slots,), np.int64)   # next lazy page
        self._slot_limit = np.zeros((n_slots,), np.int64)  # exclusive bound
        self.cushion_slots = 0              # live slots mapping the cushion
        self.prefix_cache = bool(prefix_cache)
        # (logical page, stem bytes) -> physical page, insertion-ordered so
        # eviction is oldest-first
        self._stems: "collections.OrderedDict[Tuple[int, bytes], int]" = \
            collections.OrderedDict()
        self._page_stem: Dict[int, Tuple[int, bytes]] = {}
        self.prefix_hits = 0
        self.prefix_misses = 0
        self.dirty = True                   # host table ahead of the device

    # ------------------------------------------------------------------
    # Capacity
    # ------------------------------------------------------------------

    def available(self) -> int:
        """Pages an admission may claim right now: the free list minus the
        outstanding lazy-decode reservations of live slots."""
        return len(self.free) - self.reserved

    def pages_for(self, lo: int, hi: int) -> Tuple[int, int]:
        """Logical page range [first, last) covering positions [lo, hi),
        clipped below to the first content page (pure-cushion pages are
        never materialized)."""
        first = max(self.c0, lo // self.ps)
        last = -(-hi // self.ps)
        return first, max(first, last)

    # ------------------------------------------------------------------
    # Admission / lazy growth / release
    # ------------------------------------------------------------------

    def admit(self, slot: int, prefill_end: int, need: int,
              shared: Optional[List[int]] = None) -> Optional[np.ndarray]:
        """Claim pages for a request occupying positions [0, need) whose
        admission prefill writes content up to ``prefill_end`` (= m + S).
        ``shared`` maps the first len(shared) content pages to existing
        (prefix-cache donor) physical pages instead of fresh ones.

        Returns the (P,) int32 scatter index vector for the admission-row
        page scatter — owned prompt pages at their logical index, everything
        else (cushion, shared, not-yet-mapped, beyond) pointing at the
        scratch page 0 — or None when the pool cannot host the request right
        now (caller backpressures exactly like a full slot pool)."""
        shared = shared or []
        first, prompt_last = self.pages_for(0, prefill_end)
        _, limit = self.pages_for(0, need)
        own_now = max(0, (prompt_last - first) - len(shared))
        reserve = limit - prompt_last
        if self.available() < own_now + reserve:
            self._evict_stems(own_now + reserve - self.available())
            if self.available() < own_now + reserve:
                return None
        assert not self.table[slot].any(), "slot released before re-admit"
        scatter = np.zeros((self.P,), np.int32)
        for i, page in enumerate(shared):
            self.table[slot, first + i] = page
            self.refs[page] += 1
            self.dirty = True
        for c in range(first + len(shared), prompt_last):
            page = self.free.pop()
            self.refs[page] = 1
            self.table[slot, c] = page
            scatter[c] = page
            self.dirty = True
        self.reserved += reserve
        self._slot_reserved[slot] = reserve
        self._slot_next[slot] = prompt_last
        self._slot_limit[slot] = limit
        if self.m:
            self.cushion_slots += 1
        return scatter

    def ensure_mapped(self, slot: int, pos: int) -> None:
        """Map the page holding ``pos`` (the next decode write position)
        from the slot's reservation, if it isn't yet. Called before every
        decode step for each live slot — the on-demand half of the
        allocate-on-append contract."""
        c = pos // self.ps
        while self._slot_next[slot] <= c:
            assert self._slot_next[slot] < self._slot_limit[slot], \
                "write position beyond the admission reservation"
            page = self.free.pop()
            self.refs[page] = 1
            self.table[slot, self._slot_next[slot]] = page
            self._slot_next[slot] += 1
            self._slot_reserved[slot] -= 1
            self.reserved -= 1
            self.dirty = True

    def release(self, slot: int) -> None:
        """Return the slot's pages: refcount-decrement every mapped page
        (shared donors survive until their last reader and any cache
        reference go), drop the unused reservation, zero the table row so
        the slot's frozen-pos dead writes land on scratch."""
        mapped = np.flatnonzero(self.table[slot])
        if not mapped.size:
            # never admitted (or already released): a true no-op — no
            # mutation, so no device-mirror dirtying, no gauge movement
            assert not self._slot_reserved[slot], \
                "reservation outstanding on a slot with no mapped pages"
            return
        for c in mapped:
            self._unref(int(self.table[slot, c]))
        self.table[slot] = 0
        self.dirty = True
        self.reserved -= int(self._slot_reserved[slot])
        self._slot_reserved[slot] = 0
        self._slot_next[slot] = 0
        self._slot_limit[slot] = 0
        if self.m:
            self.cushion_slots -= 1

    def _unref(self, page: int) -> None:
        self.refs[page] -= 1
        if self.refs[page] == 0:
            self.free.append(page)
            self._page_stem.pop(page, None)

    # ------------------------------------------------------------------
    # Prefix cache
    # ------------------------------------------------------------------

    def _stem_key(self, c: int, tokens: np.ndarray) -> Tuple[int, bytes]:
        # page c covers positions [c*ps, (c+1)*ps); its content is the
        # cushion tail (identical for everyone) plus the first
        # (c+1)*ps - m prompt tokens
        n = (c + 1) * self.ps - self.m
        return (c, np.ascontiguousarray(tokens[:n]).tobytes())

    def lookup_stem(self, tokens: np.ndarray) -> List[int]:
        """Longest run of cached pages matching this prompt's stem, capped
        so at least one prompt token remains for the tail prefill (the
        admission still needs last-token logits). Returns donor physical
        page ids for logical pages [c0, c0+h)."""
        if not self.prefix_cache:
            return []
        S = int(tokens.shape[0])
        pages: List[int] = []
        c = self.c0
        # full pages only, and leave >= 1 prompt token uncovered
        while (c + 1) * self.ps <= self.m + S - 1:
            page = self._stems.get(self._stem_key(c, tokens))
            if page is None:
                break
            pages.append(page)
            c += 1
        if pages:
            self.prefix_hits += 1
        else:
            self.prefix_misses += 1
        return pages

    def register_stem(self, slot: int, tokens: np.ndarray,
                      prefill_end: int) -> None:
        """After admission, publish the slot's fully-written prompt pages
        (positions < prefill_end) into the content-addressed registry. Each
        entry holds its own reference so donors outlive their writer."""
        if not self.prefix_cache:
            return
        c = self.c0
        while (c + 1) * self.ps <= prefill_end:
            key = self._stem_key(c, tokens)
            if key not in self._stems:
                page = int(self.table[slot, c])
                if page:
                    self._stems[key] = page
                    self._page_stem[page] = key
                    self.refs[page] += 1
            c += 1

    def _evict_stems(self, n: int) -> None:
        """Free up to ``n`` pages by dropping the oldest cache entries whose
        only remaining holder is the registry itself."""
        freed = 0
        for key in list(self._stems):
            if freed >= n:
                break
            page = self._stems[key]
            if self.refs[page] == 1:
                del self._stems[key]
                freed += 1
                self._unref(page)

    # ------------------------------------------------------------------
    # Gauges
    # ------------------------------------------------------------------

    def gauges(self) -> Dict[str, int]:
        return {
            "pages_total": self.n_pages,
            "pages_free": len(self.free),
            "pages_shared": int((self.refs > 1).sum()),
            "cushion_page_refs": (1 + self.cushion_slots) if self.m else 0,
        }
