"""Serving engine: batched prefill + decode with a CushionCache prefix and
configurable quantized execution (the paper's deployment story — per-tensor
*static* W8A8 is the fastest mode and the one CushionCache rescues).

The generation loop is device-resident: decode runs as one jitted
``lax.scan`` over the requested token budget, with greedy/categorical
sampling under the scan and the token trajectory accumulated on device.
The host syncs exactly twice per request — once after prefill (TTFT) and
once after the whole scan (TPOT) — instead of once per generated token.
``generate_py`` keeps the legacy per-token host loop as the A/B baseline
for the decode benchmarks.

KV cache precision is selectable (``kv_dtype="int8"`` halves decode HBM
traffic, the dominant roofline term at generation time); the cushion/sink
prefix block always stays full-precision (KVSink/IntactKV rule).

Latency accounting (TTFT/TPOT) feeds the Table-8 benchmark.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import QuantConfig
from repro.core import quantization as Q
from repro.core.calibration import CalibratedScales
from repro.core.cushioncache import cushion_fingerprint
from repro.distributed import sharding as SH
from repro.models.registry import ModelAPI
from repro.monitoring import resident_weight_bytes


def shard_params_for_serving(params, mesh):
    """Lay params out for inference on a tp mesh: TP-only serve rules
    (weights replicated over data/pod axes — FSDP sharding would all-gather
    every weight per decoded token). Prequantized {w_int | w_packed,
    w_scale, colsum} leaves ride the same rules: the int weight shards like
    its fp parent, colsum follows the parent's output axis, scales
    replicate (sharding.rules_pspec)."""
    return jax.device_put(
        params, SH.params_shardings(params, mesh, SH.serve_rules()))


def plan_quantization(api, params, qcfg: QuantConfig, cushion=None,
                      scales=None, calib_batches=None,
                      prequant: bool = False, weight_bits: int = 8):
    """Load-time quantization plan shared by ``Engine`` and
    ``ContinuousEngine``. Returns (params, scales) ready to serve:

    * ``pt_static`` with no precomputed ``scales`` calibrates them here via
      ``core.calibration.calibrate`` over ``calib_batches`` — under the
      cushion prefix when one is attached, because static scales must
      describe the *deployment* activation distribution (the cushioned
      one). Refuses to proceed with neither scales nor calibration data:
      serving pt_static on placeholder scales silently produces garbage
      logits, the exact failure this path exists to prevent.
    * ``prequant`` converts every qdot-consumed weight matrix to an
      int8-resident {w_int, w_scale, colsum} dict
      (``core.quantization.prequantize_tree``) so decode streams
      1 byte/weight; requires the pt_static deployment mode. The fp-weight
      path (prequant=False) stays available as the A/B baseline.
    * precomputed ``scales`` carrying cushion provenance
      (``core.calibration.CalibratedScales`` — `calibrate_tagged`, tune
      artifacts) are fingerprint-checked against the cushion actually
      being served and REJECTED on mismatch. A tuned cushion shifts the
      activation distribution the static ranges were fit to; serving the
      stale pair produces silently-wrong ranges, so the plan hard-fails
      and demands recalibration (or the matching artifact) instead.
    """
    if isinstance(scales, CalibratedScales):
        want, got = scales.cushion_fp, cushion_fingerprint(cushion)
        if want != got:
            raise ValueError(
                f"stale pt_static scales: calibrated under cushion "
                f"{want[:12]} but asked to serve cushion {got[:12]}; "
                f"recalibrate under the serving cushion (pass "
                f"calib_batches=) or load the matching tune artifact — "
                f"refusing to serve mismatched static ranges")
        scales = scales.scales
    if qcfg.mode == "pt_static" and scales is None:
        if calib_batches is None:
            raise ValueError(
                "pt_static serving needs calibrated site scales: pass "
                "scales= (core.calibration.calibrate) or calib_batches= "
                "to calibrate at engine load; refusing to serve on "
                "placeholder scales (silent garbage logits)")
        from repro.core.calibration import calibrate
        scales, _ = calibrate(api, params, calib_batches, qcfg,
                              cushion=cushion)
    if weight_bits not in (8, 4):
        raise ValueError(f"weight_bits must be 8 or 4, got {weight_bits}")
    if weight_bits == 4 and not prequant:
        raise ValueError(
            "weight_bits=4 is the int4-packed resident format and only "
            "exists prequantized; pass prequant=True (fp and W8A8 remain "
            "the A/B baselines)")
    if prequant:
        if qcfg.mode != "pt_static":
            raise ValueError(
                f"prequant (int8-resident weights) serves the pt_static "
                f"deployment mode only, got mode={qcfg.mode!r}")
        params = Q.prequantize_tree(params, qcfg, weight_bits=weight_bits)
    return params, scales


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, n_gen)
    ttft_ms: float
    tpot_ms: float


def cache_seq_len(max_seq: int) -> int:
    """Round a KV-cache length up to a multiple of 128 so the decode
    kernel's KV chunking divides it evenly (a ragged tail would cost a full
    cache copy per decode step). Shared by the static Engine and the
    continuous-batching pool — the invariant lives here."""
    return -(-max_seq // 128) * 128


def cushion_prefix_len(cushion) -> int:
    """Length m of the cushion/sink prefix block in a cushion artifact
    (0 when absent or stateless)."""
    if cushion is not None and "kv" in cushion:
        return int(cushion["kv"]["k"].shape[1])
    return 0


def bucket_steps(n_steps: int) -> int:
    """Round a decode-step budget up to the next power of two (min 8).

    The scanned generation loop compiles one executable per distinct step
    count; bucketing maps a varying-budget frontend onto a handful of
    executables instead of one per request size. The surplus steps run and
    are sliced away — scan steps are sequential, so the first ``n_steps``
    outputs are unaffected (cache writes past ``max_seq`` clamp into the
    last row, which only ever corrupts positions read by the discarded
    surplus steps)."""
    if n_steps <= 0:
        return 0
    b = 8
    while b < n_steps:
        b *= 2
    return b


class Engine:
    """Holds compiled prefill/decode executables for one (model, quant,
    cushion, kv_dtype) configuration.

    ``calib_batches`` / ``prequant``: the load-time quantization plan
    (``plan_quantization``). For pt_static serving, site scales are
    calibrated here (under the cushion prefix) unless precomputed ones are
    passed; ``prequant=True`` additionally converts qdot-consumed weights
    to int8-resident {w_int, w_scale, colsum} dicts so decode streams
    1 byte/weight through the W8A8 matmul path — or, with
    ``weight_bits=4``, to int4-packed {w_packed, w_scale, colsum} dicts
    (0.5 byte/weight, W4A8). ``weight_bytes_fp`` / ``weight_bytes_int8`` /
    ``weight_bytes_int4`` report the resulting resident layout.

    ``mesh``: optional tp mesh (launch/mesh.py ``make_tp_mesh``). When set,
    params are laid out with the TP-only serve rules, the KV cache shards
    along its heads axis (models/*.cache_roles), and prefill/decode trace
    under the mesh so the ``constrain`` hints in model code bind — the
    whole generation loop then runs as sharding-constrained jit with the
    pool resident across devices (no per-step host transfer; same
    compile-once/donation properties as the single-device path)."""

    def __init__(self, api: ModelAPI, params, qcfg: QuantConfig,
                 cushion=None, scales=None, max_seq: int = 2048,
                 kv_dtype=None, mesh=None, calib_batches=None,
                 prequant: bool = False, weight_bits: int = 8):
        self.api = api
        self.mesh = mesh
        params, scales = plan_quantization(
            api, params, qcfg, cushion=cushion, scales=scales,
            calib_batches=calib_batches, prequant=prequant,
            weight_bits=weight_bits)
        self.params = (shard_params_for_serving(params, mesh)
                       if mesh is not None else params)
        (self.weight_bytes_fp, self.weight_bytes_int8,
         self.weight_bytes_int4) = resident_weight_bytes(self.params)
        self.qcfg = qcfg
        self.cushion = cushion
        self.scales = scales
        self.max_seq = cache_seq_len(max_seq)
        self.kv_dtype = kv_dtype
        self.prefix_len = cushion_prefix_len(cushion)
        # served-cushion provenance, for logs and artifact cross-checks
        self.cushion_fp = cushion_fingerprint(cushion)
        self._prefill = jax.jit(
            lambda p, b, c: api.prefill(p, b, c, qcfg, cushion=cushion,
                                        scales=scales))
        self._decode = jax.jit(
            lambda p, t, pos, c: api.decode_step(p, t, pos, c, qcfg,
                                                 scales=scales))

        def gen_loop(p, tok0, pos0, cache, rng, n_steps: int, greedy: bool):
            def step(carry, _):
                tok, pos, cache, rng = carry
                logits, cache = api.decode_step(p, tok, pos, cache, qcfg,
                                                scales=scales)
                if greedy:
                    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                else:
                    rng, k = jax.random.split(rng)
                    nxt = jax.random.categorical(k, logits).astype(jnp.int32)
                return (nxt, pos + 1, cache, rng), nxt

            carry, toks = jax.lax.scan(step, (tok0, pos0, cache, rng),
                                       None, length=n_steps)
            return jnp.concatenate([tok0[None], toks], axis=0)

        # n_steps/greedy are static: each distinct scan length compiles its
        # own executable. `generate` buckets the requested budget
        # (bucket_steps) so a varying-budget frontend compiles one scan per
        # bucket, not per request size.
        self._gen_loop = jax.jit(gen_loop, static_argnums=(5, 6))

    def _init_cache(self, batch: int):
        cache = self.api.init_cache(batch, self.max_seq,
                                    kv_dtype=self.kv_dtype,
                                    prefix_len=self.prefix_len)
        if self.mesh is not None:
            cache = jax.device_put(cache, SH.cache_shardings(
                self.api.cache_roles(self.kv_dtype), cache, self.mesh))
        return cache

    def _run_prefill(self, batch: Dict[str, Any]):
        """Prefill + first token. Returns (tok, pos, cache, ttft_ms)."""
        B = batch["tokens"].shape[0]
        with SH.use_mesh(self.mesh):
            cache = self._init_cache(B)
            t0 = time.perf_counter()
            logits, cache, pos = self._prefill(self.params, batch, cache)
            logits = logits[:, -1] if logits.ndim == 3 else logits
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            tok.block_until_ready()
        return tok, pos, cache, (time.perf_counter() - t0) * 1e3

    def generate(self, batch: Dict[str, Any], n_tokens: int,
                 greedy: bool = True, rng=None) -> GenerationResult:
        tok, pos, cache, ttft = self._run_prefill(batch)
        t1 = time.perf_counter()
        g = bool(greedy or rng is None)
        key = rng if rng is not None else jax.random.PRNGKey(0)
        n_steps = max(0, n_tokens - 1)
        # bucketed scan length: requests in the same bucket share one
        # compiled executable; surplus steps are sliced away below.
        with SH.use_mesh(self.mesh):
            toks = self._gen_loop(self.params, tok, pos, cache, key,
                                  bucket_steps(n_steps), g)
        if toks.shape[0] > 1 + n_steps:
            toks = toks[:1 + n_steps]
        toks.block_until_ready()    # single host sync for the whole loop
        # tpot charges the (bucket-padded) loop to the *delivered* tokens —
        # honest latency per useful token, slightly pessimistic off-bucket.
        # A <=1-token request has no "per subsequent token" latency: report
        # 0.0 instead of the 0-step scan's dispatch overhead.
        tpot = (0.0 if n_tokens <= 1
                else (time.perf_counter() - t1) * 1e3 / (n_tokens - 1))
        return GenerationResult(tokens=np.asarray(toks).T, ttft_ms=ttft,
                                tpot_ms=tpot)

    def generate_py(self, batch: Dict[str, Any], n_tokens: int,
                    greedy: bool = True, rng=None) -> GenerationResult:
        """Legacy per-token host loop (one device->host sync per token);
        kept as the reference/baseline for the decode benchmarks and the
        scan-equivalence tests."""
        tok, pos, cache, ttft = self._run_prefill(batch)
        out = [np.asarray(tok)]
        t1 = time.perf_counter()
        with SH.use_mesh(self.mesh):
            for _ in range(n_tokens - 1):
                logits, cache = self._decode(self.params, tok, pos, cache)
                if greedy or rng is None:
                    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                else:
                    rng, k = jax.random.split(rng)
                    tok = jax.random.categorical(k, logits).astype(jnp.int32)
                pos = pos + 1
                out.append(np.asarray(tok))
        jax.block_until_ready(tok)
        tpot = (0.0 if n_tokens <= 1
                else (time.perf_counter() - t1) * 1e3 / (n_tokens - 1))
        return GenerationResult(tokens=np.stack(out, 1), ttft_ms=ttft,
                                tpot_ms=tpot)
