"""Serving engine: batched prefill + decode with a CushionCache prefix and
configurable quantized execution (the paper's deployment story — per-tensor
*static* W8A8 is the fastest mode and the one CushionCache rescues).

Latency accounting (TTFT/TPOT) feeds the Table-8 benchmark.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import QuantConfig
from repro.models.registry import ModelAPI


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, n_gen)
    ttft_ms: float
    tpot_ms: float


class Engine:
    """Holds compiled prefill/decode executables for one (model, quant,
    cushion) configuration."""

    def __init__(self, api: ModelAPI, params, qcfg: QuantConfig,
                 cushion=None, scales=None, max_seq: int = 2048):
        self.api = api
        self.params = params
        self.qcfg = qcfg
        self.cushion = cushion
        self.scales = scales
        self.max_seq = max_seq
        self._prefill = jax.jit(
            lambda p, b, c: api.prefill(p, b, c, qcfg, cushion=cushion,
                                        scales=scales))
        self._decode = jax.jit(
            lambda p, t, pos, c: api.decode_step(p, t, pos, c, qcfg,
                                                 scales=scales))

    def generate(self, batch: Dict[str, Any], n_tokens: int,
                 greedy: bool = True, rng=None) -> GenerationResult:
        B = batch["tokens"].shape[0]
        cache = self.api.init_cache(B, self.max_seq)

        t0 = time.perf_counter()
        logits, cache, pos = self._prefill(self.params, batch, cache)
        logits = logits[:, -1] if logits.ndim == 3 else logits
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        tok.block_until_ready()
        ttft = (time.perf_counter() - t0) * 1e3

        out = [np.asarray(tok)]
        t1 = time.perf_counter()
        for i in range(n_tokens - 1):
            logits, cache = self._decode(self.params, tok, pos, cache)
            if greedy or rng is None:
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                rng, k = jax.random.split(rng)
                tok = jax.random.categorical(k, logits).astype(jnp.int32)
            pos = pos + 1
            out.append(np.asarray(tok))
        jax.block_until_ready(tok)
        tpot = (time.perf_counter() - t1) * 1e3 / max(1, n_tokens - 1)
        return GenerationResult(tokens=np.stack(out, 1), ttft_ms=ttft,
                                tpot_ms=tpot)
