"""Continuous-batching serving scheduler: a fixed pool of cache slots that
requests flow through independently (admit -> prefill -> lock-step decode ->
retire -> recycle), instead of the static Engine's all-start-together batch.

Design
------
* The pool is ONE device cache of ``n_slots`` rows plus three per-row
  vectors: ``pos`` ((B,) int32 decode positions), ``tok`` ((B,) int32 last
  sampled tokens) and a host-side ``live`` mask. Decode runs one jitted
  step over the whole pool regardless of how many slots are live — dead
  rows are *compute-masked* (their pos is frozen, their sampled token
  forced to 0, their output discarded), never resized away, so the step
  executable compiles exactly once.
* Admission prefills the request alone (B=1, cushion attached) and
  scatters the full prefilled cache row into its slot along the family's
  ``CACHE_BATCH_AXES``. Scattering the *whole* row re-writes the cushion
  block [0:m) bit-identically on every recycle (KVSink/IntactKV: the fp
  sink block is never evicted and never inherited stale from the previous
  occupant) and leaves any stale content KV beyond the new request's
  extent masked off by the slot's own ``pos``. Axes entries may be nested
  dicts (a per-leaf batch-axis subtree) for families whose cache is a
  state *tree* rather than flat arrays — ssm's per-pair mLSTM/sLSTM
  states scatter exactly like hybrid's Mamba leaves.
* Per-row positions are threaded down to the attention kernel: RoPE
  offsets, cache writes and masking are all per-slot
  (``common.attention_decode_kv`` / ``kernels/flash_decode.py``), so slots
  prefilled at different times decode together in one lock-step batch.
  Recurrent families (ssm, hybrid's Mamba leaves) ignore ``pos``; their
  dead rows advance garbage state that the full-row admission scatter
  overwrites before the slot is ever read again.
* EOS/budget retirement happens host-side on the one per-step sync that
  reads the sampled tokens; the freed slot is recycled by the next
  admission. TTFT/TPOT are tracked per request; pool occupancy lands in
  ``monitoring.ServeStats``.

Incremental API (the replica router's contract, serving/router.py):
``start()`` resets the pool and opens a serving session; ``try_admit(req)``
admits into a free slot (False when the pool is full — the caller owns
queueing/backpressure); ``step()`` runs one lock-step decode and retires
finished slots; ``cancel(uid)`` frees a live slot without a result
(deadline expiry / failover); ``pop_finished()`` drains completed outputs.
``run(trace)`` — the single-engine trace replay — is built entirely on
these hooks, and drains gracefully on ``KeyboardInterrupt``: admission
stops, live slots decode to completion, and partial results are returned
with ``stats.interrupted`` set.

Tensor parallelism: pass a ``mesh`` (launch/mesh.py ``make_tp_mesh``) and
the pool shards along the family's ``cache_roles`` axes (KV heads, Mamba
channels) with params under the TP-only serve rules; admission rows share
the pool layout so the slot scatter stays shard-local, and the lock-step
decode runs as one sharding-constrained jitted step with the pool resident
across devices (the per-step host sync still reads only the (B,) sampled
tokens, never the pool).

Quantization: the engine shares the static ``Engine``'s load-time plan
(``serving.engine.plan_quantization``) — pt_static site scales calibrated
under the cushion at construction, optionally with ``prequant=True``
int8-resident weights. ``kv_dtype="int8"`` serves a quantized KV pool with
*per-slot* dequant scales: every admission's B=1 prefill calibrates
per-(layer,head) scales from its own prompt (``write_prompt_kv``), the
slot scatter carries them into (L, n_slots, K) pool leaves alongside the
KV rows, and decode quantizes/dequantizes each row with its own scales
(kernels/flash_decode.py per-row scale routing). The fp cushion block
kc/vc is batch-free and rewritten bit-identically on every admission
(KVSink/IntactKV).

Scope: greedy decoding for every registry family with a
``CACHE_BATCH_AXES`` slot layout — dense / moe / vlm / hybrid (KV pools,
int8-capable) plus ssm and encdec (fp state/KV pools). When every request
starts together with one shared budget, prefer the static ``Engine``: its
device-resident scan syncs twice per request instead of once per token.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import QuantConfig
from repro.distributed import sharding as SH
from repro.models.registry import ModelAPI
from repro.monitoring import ServeStats, resident_weight_bytes
from repro.serving.engine import (cache_seq_len, cushion_prefix_len,
                                  plan_quantization,
                                  shard_params_for_serving)


@dataclasses.dataclass
class Request:
    """One generation request. batch: B=1 model inputs ({"tokens": (1, S)}
    plus "patches"/"frames" where the family needs them). arrival_s is the
    trace-relative arrival time (0.0 = available immediately).
    deadline_s, when set, is the trace-relative instant after which the
    request is worthless — the router rejects it from the queue or cancels
    it mid-decode once the deadline passes."""
    uid: int
    batch: Dict[str, Any]
    max_new_tokens: int
    eos_id: Optional[int] = None
    arrival_s: float = 0.0
    deadline_s: Optional[float] = None


@dataclasses.dataclass
class RequestOutput:
    uid: int
    tokens: np.ndarray          # (n_gen,) int32 — includes EOS if emitted
    ttft_ms: float              # admission -> first token (prefill wall)
    tpot_ms: float              # mean wall per subsequent token (0.0 if <2)
    slot: int
    admitted_s: float           # trace-relative admission completion
    finished_s: float           # trace-relative retirement
    latency_s: float            # arrival -> retirement


class _Slot:
    __slots__ = ("req", "tokens", "t_first", "t_admit", "used")

    def __init__(self) -> None:
        self.req: Optional[Request] = None
        self.tokens: List[int] = []
        self.t_first = 0.0
        self.t_admit = 0.0
        self.used = False       # has ever held a request (recycle counter)


def _scatter_row(dst, src, spec, slot):
    """Write a B=1 admission row into pool slot ``slot``. ``spec`` is the
    family's batch-axis entry: an int (flat cache leaf) or a nested dict
    of per-leaf axes (state trees — ssm's stacked mLSTM/sLSTM states)."""
    if isinstance(spec, dict):
        return {k: (_scatter_row(dst[k], src[k], spec[k], slot)
                    if k in spec else dst[k]) for k in dst}
    return jax.lax.dynamic_update_slice_in_dim(
        dst, src.astype(dst.dtype), slot, axis=spec)


class ContinuousEngine:
    """Continuous-batching counterpart of ``Engine`` (one compiled step
    executable shared by every pool composition; see module docstring)."""

    def __init__(self, api: ModelAPI, params, qcfg: QuantConfig,
                 n_slots: int = 4, max_seq: int = 2048, cushion=None,
                 scales=None, stats: Optional[ServeStats] = None,
                 mesh=None, kv_dtype=None, calib_batches=None,
                 prequant: bool = False):
        self.api = api
        self.mesh = mesh
        params, scales = plan_quantization(
            api, params, qcfg, cushion=cushion, scales=scales,
            calib_batches=calib_batches, prequant=prequant)
        self.params = (shard_params_for_serving(params, mesh)
                       if mesh is not None else params)
        self.qcfg = qcfg
        self.n_slots = n_slots
        self.max_seq = cache_seq_len(max_seq)
        self.cushion = cushion
        self.scales = scales
        self.kv_dtype = kv_dtype
        self.prefix_len = cushion_prefix_len(cushion)
        axes = dict(api.cache_batch_axes)   # raises for unsupported families
        # recurrent-only caches (ssm) have no sequence axis: the pool never
        # runs out of positions, so the max_seq admission check is vacuous
        self._seq_cache = any(k in axes for k in ("k", "v"))
        if kv_dtype is not None:
            # per-slot dequant scales travel with their KV rows: the slot
            # scatter writes the admission prefill's (L,1,K) scales into
            # the pool's (L,n_slots,K) leaves at the same batch axis
            axes.update({"k_scale": 1, "v_scale": 1})
        self._axes = axes
        self.stats = stats if stats is not None else ServeStats(n_slots=n_slots)
        self.stats.n_slots = n_slots
        self.stats.weight_bytes_fp, self.stats.weight_bytes_int8 = \
            resident_weight_bytes(self.params)

        self._prefill = jax.jit(
            lambda p, b, c: api.prefill(p, b, c, qcfg, cushion=cushion,
                                        scales=scales))

        def admit(cache, row, slot, pos, tok, rpos, tok0):
            cache = dict(cache)
            for key, ax in axes.items():
                cache[key] = _scatter_row(cache[key], row[key], ax, slot)
            for key in ("kc", "vc"):
                # batch-free fp cushion block: rewritten wholesale from the
                # admission row — bit-identical on every recycle, exactly
                # the KVSink/IntactKV rule the fp pools honour via the
                # full-row scatter
                if key in cache:
                    cache[key] = row[key].astype(cache[key].dtype)
            return (cache, pos.at[slot].set(jnp.asarray(rpos, jnp.int32)),
                    tok.at[slot].set(jnp.asarray(tok0, jnp.int32)))

        def step(p, tok, pos, live, cache):
            logits, cache = api.decode_step(p, tok, pos, cache, qcfg,
                                            scales=scales)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            nxt = jnp.where(live, nxt, 0)          # dead rows feed token 0
            pos = jnp.where(live, pos + 1, pos)    # freeze retired offsets
            return nxt, pos, cache

        # donate the pool cache: the old buffer is dead once self.cache is
        # rebound, and without donation every per-layer cache write would
        # materialize a pool-sized copy per decode step (and 2x peak HBM).
        # Backends that can't donate (CPU) just ignore the hint.
        self._admit = jax.jit(admit, donate_argnums=(0,))
        self._step = jax.jit(step, donate_argnums=(4,))
        self.start()

    # ------------------------------------------------------------------
    # Pool state
    # ------------------------------------------------------------------

    def _init_cache(self, batch: int):
        return self.api.init_cache(batch, self.max_seq,
                                   kv_dtype=self.kv_dtype,
                                   prefix_len=self.prefix_len,
                                   per_slot_scales=self.kv_dtype is not None)

    def _reset_pool(self) -> None:
        self.cache = self._shard_cache(self._init_cache(self.n_slots))
        self.pos = jnp.zeros((self.n_slots,), jnp.int32)
        self.tok = jnp.zeros((self.n_slots,), jnp.int32)
        self.live = np.zeros((self.n_slots,), bool)
        self._slots = [_Slot() for _ in range(self.n_slots)]

    def _shard_cache(self, cache):
        """Lay a pool (or B=1 admission row) out over the tp mesh along the
        family's cache_roles axes (heads / Mamba channels; see
        models/*.cache_roles). The admission row shares the pool's layout so
        the slot scatter is shard-local, never a reshard."""
        if self.mesh is None:
            return cache
        return jax.device_put(cache, SH.cache_shardings(
            self.api.cache_roles(self.kv_dtype,
                                 per_slot_scales=self.kv_dtype is not None),
            cache, self.mesh))

    def _positions_needed(self, req: Request) -> int:
        S = req.batch["tokens"].shape[1]
        if "patches" in req.batch:
            S += req.batch["patches"].shape[1]
        return self.prefix_len + S + req.max_new_tokens

    # ------------------------------------------------------------------
    # Incremental serving API (the replica router's contract)
    # ------------------------------------------------------------------

    def start(self) -> None:
        """Open a serving session: reset the pool, the occupancy stats and
        the result buffers. Compiled executables are kept."""
        with SH.use_mesh(self.mesh):
            self._reset_pool()
        self.stats.reset()
        self._results: Dict[int, RequestOutput] = {}
        self._ttft: Dict[int, float] = {}
        self._t0 = time.perf_counter()

    def now(self) -> float:
        """Seconds since ``start()`` (the session-relative clock every
        timestamp in ``RequestOutput`` is expressed in)."""
        return time.perf_counter() - self._t0

    def free_slots(self) -> List[int]:
        return [int(i) for i in np.flatnonzero(~self.live)
                if self._slots[i].req is None]

    @property
    def live_count(self) -> int:
        return int(self.live.sum())

    def live_requests(self) -> List[Request]:
        """Requests currently occupying a slot (the router fails these over
        to surviving replicas when this engine dies)."""
        return [s.req for s in self._slots if s.req is not None]

    def try_admit(self, req: Request) -> bool:
        """Admit ``req`` into a free slot (B=1 prefill + full-row scatter).
        Returns False when no slot is free — queueing and backpressure are
        the caller's job, the pool itself never buffers."""
        free = self.free_slots()
        if not free:
            return False
        self._admit_request(req, free[0])
        return True

    def step(self) -> List[int]:
        """One lock-step decode over the whole pool; retires slots that hit
        EOS or budget. Returns the uids retired this step (their outputs
        are ready in ``pop_finished``). No-op when nothing is live."""
        if not self.live.any():
            return []
        with SH.use_mesh(self.mesh):
            self.tok, self.pos, self.cache = self._step(
                self.params, self.tok, self.pos, jnp.asarray(self.live),
                self.cache)
        toks = np.asarray(self.tok)     # the one host sync per step
        self.stats.steps += 1
        self.stats.live_slot_steps += int(self.live.sum())
        retired: List[int] = []
        for slot in np.flatnonzero(self.live):
            s = self._slots[slot]
            req = s.req
            s.tokens.append(int(toks[slot]))
            if (len(s.tokens) >= req.max_new_tokens
                    or (req.eos_id is not None
                        and s.tokens[-1] == req.eos_id)):
                retired.append(req.uid)
                self._retire(int(slot))
        return retired

    def cancel(self, uid: int) -> bool:
        """Free the slot holding ``uid`` without producing a result
        (deadline expiry mid-decode, failover bookkeeping). The slot's
        stale KV needs no scrubbing: the next admission's full-row scatter
        overwrites it. Returns False if ``uid`` is not live here."""
        for slot, s in enumerate(self._slots):
            if s.req is not None and s.req.uid == uid:
                self.live[slot] = False
                s.req = None
                self._ttft.pop(uid, None)
                self.stats.canceled += 1
                return True
        return False

    def pop_finished(self) -> List[RequestOutput]:
        """Drain completed outputs (uid-sorted) accumulated since the last
        call."""
        out = [self._results[u] for u in sorted(self._results)]
        self._results = {}
        return out

    # ------------------------------------------------------------------
    # Admission / retirement internals
    # ------------------------------------------------------------------

    def _admit_request(self, req: Request, slot: int) -> None:
        need = self._positions_needed(req)
        if self._seq_cache and need > self.max_seq:
            raise ValueError(
                f"request {req.uid} needs {need} positions "
                f"(prefix {self.prefix_len} + prompt + budget) "
                f"> pool max_seq {self.max_seq}")
        tpf = time.perf_counter()
        with SH.use_mesh(self.mesh):
            row = self._shard_cache(self._init_cache(1))
            logits, row, rpos = self._prefill(self.params, req.batch, row)
            logits = logits[:, -1] if logits.ndim == 3 else logits
            tok0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)[0]
            self.cache, self.pos, self.tok = self._admit(
                self.cache, row, jnp.asarray(slot, jnp.int32), self.pos,
                self.tok, rpos, tok0)
        first = int(jax.block_until_ready(tok0))
        now = time.perf_counter()

        s = self._slots[slot]
        if s.used:
            self.stats.recycles += 1
        s.used = True
        s.req = req
        s.tokens = [first]
        s.t_admit = now - self._t0
        s.t_first = now
        self.stats.admitted += 1
        ttft = (now - tpf) * 1e3
        self._ttft[req.uid] = ttft
        done = (req.max_new_tokens <= 1
                or (req.eos_id is not None and first == req.eos_id))
        self.live[slot] = not done
        if done:
            self._retire(slot)

    def _retire(self, slot: int) -> None:
        s = self._slots[slot]
        req = s.req
        assert req is not None
        now = time.perf_counter()
        n = len(s.tokens)
        tpot = 0.0 if n <= 1 else (now - s.t_first) * 1e3 / (n - 1)
        self._results[req.uid] = RequestOutput(
            uid=req.uid, tokens=np.asarray(s.tokens, np.int32),
            ttft_ms=self._ttft[req.uid], tpot_ms=tpot, slot=slot,
            admitted_s=s.t_admit, finished_s=now - self._t0,
            latency_s=(now - self._t0) - req.arrival_s)
        self.live[slot] = False
        s.req = None
        self.stats.finished += 1

    # ------------------------------------------------------------------
    # Trace replay
    # ------------------------------------------------------------------

    def run(self, requests: Sequence[Request]) -> List[RequestOutput]:
        """Replay a trace: admit each request once its arrival time passes
        and a slot is free (FIFO), decode the pool in lock-step, return
        outputs sorted by uid. Re-entrant: the pool and the occupancy
        stats are reset per run (compiled executables are kept).

        ``KeyboardInterrupt`` (ctrl-C / the launcher's SIGTERM handler)
        triggers a graceful drain instead of dying mid-step: admission
        stops, live slots decode to completion, the queued remainder is
        dropped, and the completed outputs are returned with
        ``stats.interrupted`` set. A second interrupt aborts immediately."""
        self.start()
        queue = collections.deque(
            sorted(requests, key=lambda r: (r.arrival_s, r.uid)))
        done: Dict[int, RequestOutput] = {}
        draining = False

        while queue or self.live.any():
            try:
                if draining:
                    if not self.live.any():
                        break
                else:
                    now = self.now()
                    # admit every arrived request that fits a free slot
                    while (queue and queue[0].arrival_s <= now
                           and self.try_admit(queue[0])):
                        queue.popleft()
                    if not self.live.any():
                        if queue:   # pool idle, next arrival in the future
                            time.sleep(min(1e-3, max(
                                0.0, queue[0].arrival_s - self.now())))
                        for o in self.pop_finished():
                            done[o.uid] = o
                        continue
                self.step()
                for o in self.pop_finished():
                    done[o.uid] = o
            except KeyboardInterrupt:
                if draining:
                    raise               # second interrupt: stop for real
                draining = True
                self.stats.interrupted = True

        for o in self.pop_finished():
            done[o.uid] = o
        return [done[u] for u in sorted(done)]
