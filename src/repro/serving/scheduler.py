"""Continuous-batching serving scheduler: a fixed pool of cache slots that
requests flow through independently (admit -> prefill -> lock-step decode ->
retire -> recycle), instead of the static Engine's all-start-together batch.

Design
------
* The pool is ONE device cache of ``n_slots`` rows plus three per-row
  vectors: ``pos`` ((B,) int32 decode positions), ``tok`` ((B,) int32 last
  sampled tokens) and a host-side ``live`` mask. Decode runs one jitted
  step over the whole pool regardless of how many slots are live — dead
  rows are *compute-masked* (their pos is frozen, their sampled token
  forced to 0, their output discarded), never resized away, so the step
  executable compiles exactly once.
* Admission prefills the request alone (B=1, cushion attached) and
  scatters the full prefilled cache row into its slot along the family's
  ``CACHE_BATCH_AXES``. Scattering the *whole* row re-writes the cushion
  block [0:m) bit-identically on every recycle (KVSink/IntactKV: the fp
  sink block is never evicted and never inherited stale from the previous
  occupant) and leaves any stale content KV beyond the new request's
  extent masked off by the slot's own ``pos``.
* Per-row positions are threaded down to the attention kernel: RoPE
  offsets, cache writes and masking are all per-slot
  (``common.attention_decode_kv`` / ``kernels/flash_decode.py``), so slots
  prefilled at different times decode together in one lock-step batch.
* EOS/budget retirement happens host-side on the one per-step sync that
  reads the sampled tokens; the freed slot is recycled by the next
  admission. TTFT/TPOT are tracked per request; pool occupancy lands in
  ``monitoring.ServeStats``.

Tensor parallelism: pass a ``mesh`` (launch/mesh.py ``make_tp_mesh``) and
the pool shards along the family's ``cache_roles`` axes (KV heads, Mamba
channels) with params under the TP-only serve rules; admission rows share
the pool layout so the slot scatter stays shard-local, and the lock-step
decode runs as one sharding-constrained jitted step with the pool resident
across devices (the per-step host sync still reads only the (B,) sampled
tokens, never the pool).

Quantization: the engine shares the static ``Engine``'s load-time plan
(``serving.engine.plan_quantization``) — pt_static site scales calibrated
under the cushion at construction, optionally with ``prequant=True``
int8-resident weights. ``kv_dtype="int8"`` serves a quantized KV pool with
*per-slot* dequant scales: every admission's B=1 prefill calibrates
per-(layer,head) scales from its own prompt (``write_prompt_kv``), the
slot scatter carries them into (L, n_slots, K) pool leaves alongside the
KV rows, and decode quantizes/dequantizes each row with its own scales
(kernels/flash_decode.py per-row scale routing). The fp cushion block
kc/vc is batch-free and rewritten bit-identically on every admission
(KVSink/IntactKV).

Scope: greedy decoding over KV pools for families with a
``CACHE_BATCH_AXES`` slot layout (dense / moe / vlm / hybrid). When every
request starts together with one shared budget, prefer the static
``Engine``: its device-resident scan syncs twice per request instead of
once per token.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import QuantConfig
from repro.distributed import sharding as SH
from repro.models.registry import ModelAPI
from repro.monitoring import ServeStats, resident_weight_bytes
from repro.serving.engine import (cache_seq_len, cushion_prefix_len,
                                  plan_quantization,
                                  shard_params_for_serving)


@dataclasses.dataclass
class Request:
    """One generation request. batch: B=1 model inputs ({"tokens": (1, S)}
    plus "patches"/"frames" where the family needs them). arrival_s is the
    trace-relative arrival time (0.0 = available immediately)."""
    uid: int
    batch: Dict[str, Any]
    max_new_tokens: int
    eos_id: Optional[int] = None
    arrival_s: float = 0.0


@dataclasses.dataclass
class RequestOutput:
    uid: int
    tokens: np.ndarray          # (n_gen,) int32 — includes EOS if emitted
    ttft_ms: float              # admission -> first token (prefill wall)
    tpot_ms: float              # mean wall per subsequent token (0.0 if <2)
    slot: int
    admitted_s: float           # trace-relative admission completion
    finished_s: float           # trace-relative retirement
    latency_s: float            # arrival -> retirement


class _Slot:
    __slots__ = ("req", "tokens", "t_first", "t_admit", "used")

    def __init__(self) -> None:
        self.req: Optional[Request] = None
        self.tokens: List[int] = []
        self.t_first = 0.0
        self.t_admit = 0.0
        self.used = False       # has ever held a request (recycle counter)


class ContinuousEngine:
    """Continuous-batching counterpart of ``Engine`` (one compiled step
    executable shared by every pool composition; see module docstring)."""

    def __init__(self, api: ModelAPI, params, qcfg: QuantConfig,
                 n_slots: int = 4, max_seq: int = 2048, cushion=None,
                 scales=None, stats: Optional[ServeStats] = None,
                 mesh=None, kv_dtype=None, calib_batches=None,
                 prequant: bool = False):
        self.api = api
        self.mesh = mesh
        params, scales = plan_quantization(
            api, params, qcfg, cushion=cushion, scales=scales,
            calib_batches=calib_batches, prequant=prequant)
        self.params = (shard_params_for_serving(params, mesh)
                       if mesh is not None else params)
        self.qcfg = qcfg
        self.n_slots = n_slots
        self.max_seq = cache_seq_len(max_seq)
        self.cushion = cushion
        self.scales = scales
        self.kv_dtype = kv_dtype
        self.prefix_len = cushion_prefix_len(cushion)
        axes = dict(api.cache_batch_axes)   # raises for unsupported families
        if kv_dtype is not None:
            # per-slot dequant scales travel with their KV rows: the slot
            # scatter writes the admission prefill's (L,1,K) scales into
            # the pool's (L,n_slots,K) leaves at the same batch axis
            axes.update({"k_scale": 1, "v_scale": 1})
        self._axes = axes
        self.stats = stats if stats is not None else ServeStats(n_slots=n_slots)
        self.stats.n_slots = n_slots
        self.stats.weight_bytes_fp, self.stats.weight_bytes_int8 = \
            resident_weight_bytes(self.params)

        self._prefill = jax.jit(
            lambda p, b, c: api.prefill(p, b, c, qcfg, cushion=cushion,
                                        scales=scales))

        def admit(cache, row, slot, pos, tok, rpos, tok0):
            cache = dict(cache)
            for key, ax in axes.items():
                cache[key] = jax.lax.dynamic_update_slice_in_dim(
                    cache[key], row[key].astype(cache[key].dtype), slot,
                    axis=ax)
            for key in ("kc", "vc"):
                # batch-free fp cushion block: rewritten wholesale from the
                # admission row — bit-identical on every recycle, exactly
                # the KVSink/IntactKV rule the fp pools honour via the
                # full-row scatter
                if key in cache:
                    cache[key] = row[key].astype(cache[key].dtype)
            return (cache, pos.at[slot].set(jnp.asarray(rpos, jnp.int32)),
                    tok.at[slot].set(jnp.asarray(tok0, jnp.int32)))

        def step(p, tok, pos, live, cache):
            logits, cache = api.decode_step(p, tok, pos, cache, qcfg,
                                            scales=scales)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            nxt = jnp.where(live, nxt, 0)          # dead rows feed token 0
            pos = jnp.where(live, pos + 1, pos)    # freeze retired offsets
            return nxt, pos, cache

        # donate the pool cache: the old buffer is dead once self.cache is
        # rebound, and without donation every per-layer cache write would
        # materialize a pool-sized copy per decode step (and 2x peak HBM).
        # Backends that can't donate (CPU) just ignore the hint.
        self._admit = jax.jit(admit, donate_argnums=(0,))
        self._step = jax.jit(step, donate_argnums=(4,))
        with SH.use_mesh(self.mesh):
            self._reset_pool()

    # ------------------------------------------------------------------
    # Pool state
    # ------------------------------------------------------------------

    def _init_cache(self, batch: int):
        return self.api.init_cache(batch, self.max_seq,
                                   kv_dtype=self.kv_dtype,
                                   prefix_len=self.prefix_len,
                                   per_slot_scales=self.kv_dtype is not None)

    def _reset_pool(self) -> None:
        self.cache = self._shard_cache(self._init_cache(self.n_slots))
        self.pos = jnp.zeros((self.n_slots,), jnp.int32)
        self.tok = jnp.zeros((self.n_slots,), jnp.int32)
        self.live = np.zeros((self.n_slots,), bool)
        self._slots = [_Slot() for _ in range(self.n_slots)]

    def _shard_cache(self, cache):
        """Lay a pool (or B=1 admission row) out over the tp mesh along the
        family's cache_roles axes (heads / Mamba channels; see
        models/*.cache_roles). The admission row shares the pool's layout so
        the slot scatter is shard-local, never a reshard."""
        if self.mesh is None:
            return cache
        return jax.device_put(cache, SH.cache_shardings(
            self.api.cache_roles(self.kv_dtype,
                                 per_slot_scales=self.kv_dtype is not None),
            cache, self.mesh))

    def _positions_needed(self, req: Request) -> int:
        S = req.batch["tokens"].shape[1]
        if "patches" in req.batch:
            S += req.batch["patches"].shape[1]
        return self.prefix_len + S + req.max_new_tokens

    # ------------------------------------------------------------------
    # Admission / retirement
    # ------------------------------------------------------------------

    def _admit_request(self, req: Request, slot: int, t0: float) -> None:
        need = self._positions_needed(req)
        if need > self.max_seq:
            raise ValueError(
                f"request {req.uid} needs {need} positions "
                f"(prefix {self.prefix_len} + prompt + budget) "
                f"> pool max_seq {self.max_seq}")
        tpf = time.perf_counter()
        with SH.use_mesh(self.mesh):
            row = self._shard_cache(self._init_cache(1))
            logits, row, rpos = self._prefill(self.params, req.batch, row)
            logits = logits[:, -1] if logits.ndim == 3 else logits
            tok0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)[0]
            self.cache, self.pos, self.tok = self._admit(
                self.cache, row, jnp.asarray(slot, jnp.int32), self.pos,
                self.tok, rpos, tok0)
        first = int(jax.block_until_ready(tok0))
        now = time.perf_counter()

        s = self._slots[slot]
        if s.used:
            self.stats.recycles += 1
        s.used = True
        s.req = req
        s.tokens = [first]
        s.t_admit = now - t0
        s.t_first = now
        self.stats.admitted += 1
        ttft = (now - tpf) * 1e3
        self._ttft[req.uid] = ttft
        done = (req.max_new_tokens <= 1
                or (req.eos_id is not None and first == req.eos_id))
        self.live[slot] = not done
        if done:
            self._retire(slot, t0)

    def _retire(self, slot: int, t0: float) -> None:
        s = self._slots[slot]
        req = s.req
        assert req is not None
        now = time.perf_counter()
        n = len(s.tokens)
        tpot = 0.0 if n <= 1 else (now - s.t_first) * 1e3 / (n - 1)
        self._results[req.uid] = RequestOutput(
            uid=req.uid, tokens=np.asarray(s.tokens, np.int32),
            ttft_ms=self._ttft[req.uid], tpot_ms=tpot, slot=slot,
            admitted_s=s.t_admit, finished_s=now - t0,
            latency_s=(now - t0) - req.arrival_s)
        self.live[slot] = False
        s.req = None
        self.stats.finished += 1

    # ------------------------------------------------------------------
    # Trace replay
    # ------------------------------------------------------------------

    def run(self, requests: Sequence[Request]) -> List[RequestOutput]:
        """Replay a trace: admit each request once its arrival time passes
        and a slot is free (FIFO), decode the pool in lock-step, return
        outputs sorted by uid. Re-entrant: the pool and the occupancy
        stats are reset per run (compiled executables are kept)."""
        with SH.use_mesh(self.mesh):
            self._reset_pool()
        self.stats.reset()
        self._results: Dict[int, RequestOutput] = {}
        self._ttft: Dict[int, float] = {}
        queue = collections.deque(
            sorted(requests, key=lambda r: (r.arrival_s, r.uid)))
        t0 = time.perf_counter()

        while queue or self.live.any():
            now = time.perf_counter() - t0
            # admit every arrived request that fits a free slot
            while queue and queue[0].arrival_s <= now:
                free = np.flatnonzero(~self.live)
                free = [i for i in free if self._slots[i].req is None]
                if not free:
                    break
                self._admit_request(queue.popleft(), int(free[0]), t0)
            if not self.live.any():
                if queue:       # pool idle, next arrival in the future
                    time.sleep(min(1e-3, max(0.0,
                               queue[0].arrival_s - (time.perf_counter() - t0))))
                continue

            with SH.use_mesh(self.mesh):
                self.tok, self.pos, self.cache = self._step(
                    self.params, self.tok, self.pos, jnp.asarray(self.live),
                    self.cache)
            toks = np.asarray(self.tok)     # the one host sync per step
            self.stats.steps += 1
            self.stats.live_slot_steps += int(self.live.sum())
            for slot in np.flatnonzero(self.live):
                s = self._slots[slot]
                req = s.req
                s.tokens.append(int(toks[slot]))
                if (len(s.tokens) >= req.max_new_tokens
                        or (req.eos_id is not None
                            and s.tokens[-1] == req.eos_id)):
                    self._retire(int(slot), t0)

        return [self._results[u] for u in sorted(self._results)]
