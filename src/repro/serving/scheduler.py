"""Continuous-batching serving scheduler: a fixed pool of cache slots that
requests flow through independently (admit -> prefill -> lock-step decode ->
retire -> recycle), instead of the static Engine's all-start-together batch.

Design
------
* The pool is ONE device cache of ``n_slots`` rows plus three per-row
  vectors: ``pos`` ((B,) int32 decode positions), ``tok`` ((B,) int32 last
  sampled tokens) and a host-side ``live`` mask. Decode runs one jitted
  step over the whole pool regardless of how many slots are live — dead
  rows are *compute-masked* (their pos is frozen, their sampled token
  forced to 0, their output discarded), never resized away, so the step
  executable compiles exactly once.
* Admission prefills the request alone (B=1, cushion attached) and
  scatters the full prefilled cache row into its slot along the family's
  ``CACHE_BATCH_AXES``. Scattering the *whole* row re-writes the cushion
  block [0:m) bit-identically on every recycle (KVSink/IntactKV: the fp
  sink block is never evicted and never inherited stale from the previous
  occupant) and leaves any stale content KV beyond the new request's
  extent masked off by the slot's own ``pos``. Axes entries may be nested
  dicts (a per-leaf batch-axis subtree) for families whose cache is a
  state *tree* rather than flat arrays — ssm's per-pair mLSTM/sLSTM
  states scatter exactly like hybrid's Mamba leaves.
* Per-row positions are threaded down to the attention kernel: RoPE
  offsets, cache writes and masking are all per-slot
  (``common.attention_decode_kv`` / ``kernels/flash_decode.py``), so slots
  prefilled at different times decode together in one lock-step batch.
  Recurrent families (ssm, hybrid's Mamba leaves) ignore ``pos``; their
  dead rows advance garbage state that the full-row admission scatter
  overwrites before the slot is ever read again.
* EOS/budget retirement happens host-side on the one per-step sync that
  reads the sampled tokens; the freed slot is recycled by the next
  admission. TTFT/TPOT are tracked per request; pool occupancy lands in
  ``monitoring.ServeStats``.

Incremental API (the replica router's contract, serving/router.py):
``start()`` resets the pool and opens a serving session; ``try_admit(req)``
admits into a free slot (False when the pool is full — the caller owns
queueing/backpressure); ``step()`` runs one lock-step decode and retires
finished slots; ``cancel(uid)`` frees a live slot without a result
(deadline expiry / failover); ``pop_finished()`` drains completed outputs.
``run(trace)`` — the single-engine trace replay — is built entirely on
these hooks, and drains gracefully on ``KeyboardInterrupt``: admission
stops, live slots decode to completion, and partial results are returned
with ``stats.interrupted`` set.

Tensor parallelism: pass a ``mesh`` (launch/mesh.py ``make_tp_mesh``) and
the pool shards along the family's ``cache_roles`` axes (KV heads, Mamba
channels) with params under the TP-only serve rules; admission rows share
the pool layout so the slot scatter stays shard-local, and the lock-step
decode runs as one sharding-constrained jitted step with the pool resident
across devices (the per-step host sync still reads only the (B,) sampled
tokens, never the pool).

Quantization: the engine shares the static ``Engine``'s load-time plan
(``serving.engine.plan_quantization``) — pt_static site scales calibrated
under the cushion at construction, optionally with ``prequant=True``
int8-resident weights. ``kv_dtype="int8"`` serves a quantized KV pool with
*per-slot* dequant scales: every admission's B=1 prefill calibrates
per-(layer,head) scales from its own prompt (``write_prompt_kv``), the
slot scatter carries them into (L, n_slots, K) pool leaves alongside the
KV rows, and decode quantizes/dequantizes each row with its own scales
(kernels/flash_decode.py per-row scale routing). The fp cushion block
kc/vc is batch-free and rewritten bit-identically on every admission
(KVSink/IntactKV).

Paged KV pool (``paged=True``): the dense per-slot rows become a flat page
store ``(L, n_pages, page_size, K, hd)`` plus a per-slot page table — KV
memory then scales with *live tokens*, not ``n_slots * max_seq``, so more
slots fit a fixed HBM budget. The host-side allocator (serving/paging.py
``PagePool``) reserves every page a request can need at admission (mid-
decode exhaustion is impossible; a full pool backpressures exactly like a
full slot pool), maps prompt pages immediately (the admission scatter
routes each logical page of the B=1 row to its physical page) and decode
pages lazily as positions cross page boundaries. The fp cushion block
leaves the per-slot rows entirely: it lives ONCE in batch-free ``kc``/
``vc`` pool leaves written at pool reset and only ever read afterwards —
the refcounted, read-only cushion page every slot maps — so recycling a
slot re-scatters content pages but never copies the sink block again.
Reads route through ``kernels/flash_decode.flash_decode_paged`` (scalar-
prefetched page table) on TPU or a gather + the contiguous jnp paths on
CPU; either way paged and contiguous pools decode token-for-token
identical traces. ``prefix_cache=True`` (fp pools only) additionally
content-addresses full prompt-stem pages so a repeated stem maps the
donor's pages read-only (refcount++) and prefills only the tail against
an extended cushion — pages are write-once, so copy-on-write degenerates
to copy-never.

Chunked prefill (``chunk_tokens``): blocking admission runs the whole B=1
prompt prefill inline, so one long prompt stalls every live decode slot —
the p99 killer under heavy traffic. With a per-step chunk budget set
(power-of-two bucketed, min 8), a prompt longer than one budget becomes a
PREFILLING *stream* instead: the slot (and, paged, the full page
reservation) is claimed up front, and the prompt is replayed one chunk per
``step()`` — round-robin across streams — into a B=1 fp staging row,
interleaved with the pool's lock-step decode. Chunk 0 attaches the cushion
(or the prefix-cache extended cushion); later chunks resume with a static
``pos_offset``, reading the cushion + earlier chunks back out of the row
as the fully-visible prefix. Only the final chunk touches the pool, via
the SAME admit scatter as blocking admission (int8 pools requantize the
finished fp row in one shot so per-slot scales still calibrate over the
whole prompt) — chunked admission is therefore token-for-token identical
to blocking, it just stops starving decode (smooth TPOT) and stops
head-of-line blocking short prompts behind long ones (p99 TTFT).
Deadlines are enforced between chunks: an expired stream frees its slot
without a result (``stats.deadline_prefill``; the router drains the uids
via ``pop_expired``). Families whose prompt pass is not a pure causal
attention-KV scan (ssm, encdec, vlm, hybrid) keep blocking admission.

Scope: greedy decoding for every registry family with a
``CACHE_BATCH_AXES`` slot layout — dense / moe / vlm / hybrid (KV pools,
int8-capable) plus ssm and encdec (fp state/KV pools; no paged mode —
nothing to page). When every request starts together with one shared
budget, prefer the static ``Engine``: its device-resident scan syncs twice
per request instead of once per token.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import QuantConfig
from repro.distributed import sharding as SH
from repro.models.registry import ModelAPI
from repro.monitoring import ServeStats, resident_weight_bytes
from repro.serving.engine import (bucket_steps, cache_seq_len,
                                  cushion_fingerprint, cushion_prefix_len,
                                  plan_quantization,
                                  shard_params_for_serving)
from repro.serving.paging import PagePool


@dataclasses.dataclass
class Request:
    """One generation request. batch: B=1 model inputs ({"tokens": (1, S)}
    plus "patches"/"frames" where the family needs them). arrival_s is the
    trace-relative arrival time (0.0 = available immediately).
    deadline_s, when set, is the trace-relative instant after which the
    request is worthless — the router rejects it from the queue or cancels
    it mid-decode once the deadline passes."""
    uid: int
    batch: Dict[str, Any]
    max_new_tokens: int
    eos_id: Optional[int] = None
    arrival_s: float = 0.0
    deadline_s: Optional[float] = None


@dataclasses.dataclass
class RequestOutput:
    uid: int
    tokens: np.ndarray          # (n_gen,) int32 — includes EOS if emitted
    ttft_ms: float              # admission -> first token (prefill wall)
    tpot_ms: float              # mean wall per subsequent token (0.0 if <2)
    slot: int
    admitted_s: float           # trace-relative admission completion
    finished_s: float           # trace-relative retirement
    latency_s: float            # arrival -> retirement


class _Slot:
    __slots__ = ("req", "tokens", "t_first", "t_admit", "used")

    def __init__(self) -> None:
        self.req: Optional[Request] = None
        self.tokens: List[int] = []
        self.t_first = 0.0
        self.t_admit = 0.0
        self.used = False       # has ever held a request (recycle counter)


class _PrefillStream:
    """A partially-admitted request (the PREFILLING slot state): its prompt
    is replayed chunk-by-chunk into a B=1 fp staging row between decode
    steps. The slot (and, paged, the full page reservation) is claimed at
    stream start; the pool itself is only touched once, at finalize, by the
    same admit scatter the blocking path uses — so a chunked admission is
    token-for-token identical to a blocking one."""
    __slots__ = ("req", "slot", "row", "toks", "base", "shared", "scatter",
                 "stem_tokens", "prefill_end", "tpf", "done", "logits",
                 "rpos")

    def __init__(self, req: Request, slot: int, row, toks, base: int,
                 shared, scatter, stem_tokens, prefill_end: int) -> None:
        self.req = req
        self.slot = slot
        self.row = row              # B=1 fp staging cache
        self.toks = toks            # (1, total) prompt tokens (stem-trimmed)
        self.base = base            # chunk 0 position origin (prefix / stem)
        self.shared = shared        # prefix-cache donor pages (chunk 0)
        self.scatter = scatter      # paged admission scatter vector
        self.stem_tokens = stem_tokens
        self.prefill_end = prefill_end
        self.tpf = time.perf_counter()
        self.done = 0               # prompt tokens prefilled so far
        self.logits = None          # last chunk's logits (first token)
        self.rpos = None

    @property
    def total(self) -> int:
        return int(self.toks.shape[1])


def _scatter_row(dst, src, spec, slot):
    """Write a B=1 admission row into pool slot ``slot``. ``spec`` is the
    family's batch-axis entry: an int (flat cache leaf) or a nested dict
    of per-leaf axes (state trees — ssm's stacked mLSTM/sLSTM states)."""
    if isinstance(spec, dict):
        return {k: (_scatter_row(dst[k], src[k], spec[k], slot)
                    if k in spec else dst[k]) for k in dst}
    return jax.lax.dynamic_update_slice_in_dim(
        dst, src.astype(dst.dtype), slot, axis=spec)


# adaptive chunked-prefill budget bounds (chunk_tokens="auto"): the
# per-step budget slides between these with decode pressure — both ends of
# the power-of-two bucket family, so auto mode compiles the same chunk
# executables a fixed budget would
_AUTO_CHUNK_MAX = 256
_AUTO_CHUNK_MIN = 8


class ContinuousEngine:
    """Continuous-batching counterpart of ``Engine`` (one compiled step
    executable shared by every pool composition; see module docstring)."""

    def __init__(self, api: ModelAPI, params, qcfg: QuantConfig,
                 n_slots: int = 4, max_seq: int = 2048, cushion=None,
                 scales=None, stats: Optional[ServeStats] = None,
                 mesh=None, kv_dtype=None, calib_batches=None,
                 prequant: bool = False, weight_bits: int = 8,
                 paged: bool = False,
                 page_size: int = 64, n_pages: Optional[int] = None,
                 prefix_cache: bool = False,
                 chunk_tokens: Optional[Union[int, str]] = None):
        self.api = api
        self.mesh = mesh
        params, scales = plan_quantization(
            api, params, qcfg, cushion=cushion, scales=scales,
            calib_batches=calib_batches, prequant=prequant,
            weight_bits=weight_bits)
        self.params = (shard_params_for_serving(params, mesh)
                       if mesh is not None else params)
        self.qcfg = qcfg
        self.n_slots = n_slots
        self.max_seq = cache_seq_len(max_seq)
        self.cushion = cushion
        self.scales = scales
        self.kv_dtype = kv_dtype
        self.prefix_len = cushion_prefix_len(cushion)
        # served-cushion provenance (matches Engine.cushion_fp, so a router
        # or launcher can assert every replica serves the same artifact)
        self.cushion_fp = cushion_fingerprint(cushion)
        axes = dict(api.cache_batch_axes)   # raises for unsupported families
        # recurrent-only caches (ssm) have no sequence axis: the pool never
        # runs out of positions — the max_seq admission capacity check only
        # applies to families with a sequence cache
        self._seq_cache = any(k in axes for k in ("k", "v"))
        if kv_dtype is not None:
            # per-slot dequant scales travel with their KV rows: the slot
            # scatter writes the admission prefill's (L,1,K) scales into
            # the pool's (L,n_slots,K) leaves at the same batch axis
            axes.update({"k_scale": 1, "v_scale": 1})
        self._axes = axes

        self.paged = bool(paged)
        self.page_size = page_size
        self._paged_leaves = api.paged_kv_leaves
        if self.paged:
            if not self._paged_leaves:
                raise ValueError(
                    "paged=True needs a pageable sequence cache "
                    "(PAGED_KV_LEAVES); this family's cache is per-request "
                    "state with nothing to page")
            if page_size % 8:
                raise ValueError(
                    f"page_size {page_size} must be sublane-aligned "
                    f"(multiple of 8)")
            if self.max_seq % page_size:
                raise ValueError(
                    f"page_size {page_size} must divide the pool max_seq "
                    f"{self.max_seq}")
            if prefix_cache and kv_dtype is not None:
                raise ValueError(
                    "prefix_cache shares fp pages only: int8 donor pages "
                    "are quantized with the donor slot's dequant scales "
                    "and cannot be read under another slot's")
        self._P = self.max_seq // page_size
        c0 = self.prefix_len // page_size
        if n_pages is None:
            # worst case every slot owns all its content pages: paging then
            # never backpressures where the dense pool wouldn't (benchmarks
            # pass a smaller pool to realize the memory win)
            n_pages = n_slots * (self._P - c0) + 1
        self.n_pages = n_pages
        self._prefix_cache = bool(prefix_cache)
        # non-paged leaves (int8 scales, hybrid's Mamba state) keep their
        # dense per-slot rows and the plain slot scatter
        self._paged_axes = {k: v for k, v in axes.items()
                            if k not in self._paged_leaves}

        self.stats = stats if stats is not None else ServeStats(n_slots=n_slots)
        self.stats.n_slots = n_slots
        (self.stats.weight_bytes_fp, self.stats.weight_bytes_int8,
         self.stats.weight_bytes_int4) = resident_weight_bytes(self.params)

        self._prefill = jax.jit(
            lambda p, b, c: api.prefill(p, b, c, qcfg, cushion=cushion,
                                        scales=scales))
        # prefix-cache tail prefill: the cushion is a traced argument (the
        # shared stem extends it), one compile per (stem pages, tail) shape
        self._prefill_cu = jax.jit(
            lambda p, b, c, cu: api.prefill(p, b, c, qcfg, cushion=cu,
                                            scales=scales))
        # chunked admission: chunk k>0 replays tokens [done:done+c) on the
        # B=1 fp staging row with a static pos_offset — the cushion and all
        # earlier chunks are read back out of the row as the visible prefix.
        # One compile per (pos_offset, chunk shape) pair, the same profile
        # as the prefix-cache tail path above.
        self._prefill_re = jax.jit(
            lambda p, b, c, po: api.prefill(p, b, c, qcfg, scales=scales,
                                            pos_offset=po),
            static_argnums=(3,))
        self._finalize_int8 = jax.jit(
            lambda row, S: api.finalize_staged_kv(
                row, self._init_cache(1), cushion, S),
            static_argnums=(1,))
        self.chunk_tokens: Optional[int] = None
        self.chunk_auto = False
        if chunk_tokens == "auto":
            # adaptive budget: the per-chunk token budget tracks decode
            # pressure (see _chunk_budget) — big chunks when the pool
            # idles (fast TTFT), small chunks when decode slots are
            # near-full (each chunk stalls every live decoder, so a busy
            # pool trades the prefiller's TTFT for the pool's TPOT)
            self.chunk_auto = True
            self.chunk_tokens = _AUTO_CHUNK_MAX
        elif chunk_tokens is not None:
            if isinstance(chunk_tokens, str) or chunk_tokens < 1:
                raise ValueError(f"chunk_tokens {chunk_tokens!r} must be "
                                 f">= 1 or the string 'auto'")
            # the per-step prefill token budget, bucketed to the power-of-
            # two family (min 8, PR 2's bucketing) so chunk executables are
            # shared across prompt lengths; prompts at or under one budget
            # admit blocking (a stream would only add staging overhead).
            # Families without chunk-resumable prefill (ssm, encdec, vlm,
            # hybrid) silently keep blocking admission.
            self.chunk_tokens = bucket_steps(int(chunk_tokens))

        def admit(cache, row, slot, pos, tok, rpos, tok0):
            cache = dict(cache)
            for key, ax in axes.items():
                cache[key] = _scatter_row(cache[key], row[key], ax, slot)
            for key in ("kc", "vc"):
                # batch-free fp cushion block: rewritten wholesale from the
                # admission row — bit-identical on every recycle, exactly
                # the KVSink/IntactKV rule the fp pools honour via the
                # full-row scatter
                if key in cache:
                    cache[key] = row[key].astype(cache[key].dtype)
            return (cache, pos.at[slot].set(jnp.asarray(rpos, jnp.int32)),
                    tok.at[slot].set(jnp.asarray(tok0, jnp.int32)))

        def admit_paged(cache, row, slot, pos, tok, rpos, tok0, scatter_idx):
            # route each logical page of the B=1 row to its physical page:
            # owned prompt pages land at their allocator-assigned index,
            # everything else (cushion positions, shared donor pages, pages
            # beyond the prompt) at the don't-care scratch page 0. The
            # shared kc/vc cushion leaves are deliberately untouched —
            # written once at pool reset, read-only ever after.
            cache = dict(cache)
            for key in self._paged_leaves:
                rp = row[key][:, 0]             # (L, max_seq, K, hd)
                rp = rp.reshape(rp.shape[0], self._P, self.page_size,
                                *rp.shape[2:])
                cache[key] = cache[key].at[:, scatter_idx].set(
                    rp.astype(cache[key].dtype))
            for key, ax in self._paged_axes.items():
                cache[key] = _scatter_row(cache[key], row[key], ax, slot)
            return (cache, pos.at[slot].set(jnp.asarray(rpos, jnp.int32)),
                    tok.at[slot].set(jnp.asarray(tok0, jnp.int32)))

        def step(p, tok, pos, live, cache, cu):
            # cu: the paged pool's shared read-only cushion block, passed
            # OUTSIDE the donated cache so its buffers are never consumed —
            # the same two device arrays serve every step of the engine's
            # lifetime (empty dict for contiguous pools, whose cushion
            # lives inside the cache rows / kc leaves)
            full = dict(cache)
            full.update(cu)
            logits, full = api.decode_step(p, tok, pos, full, qcfg,
                                           scales=scales)
            out_cache = {k: full[k] for k in cache}
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            nxt = jnp.where(live, nxt, 0)          # dead rows feed token 0
            pos = jnp.where(live, pos + 1, pos)    # freeze retired offsets
            return nxt, pos, out_cache

        # donate the pool cache: the old buffer is dead once self.cache is
        # rebound, and without donation every per-layer cache write would
        # materialize a pool-sized copy per decode step (and 2x peak HBM).
        # Backends that can't donate (CPU) just ignore the hint.
        self._admit = jax.jit(admit, donate_argnums=(0,))
        self._admit_paged = jax.jit(admit_paged, donate_argnums=(0,))
        self._step = jax.jit(step, donate_argnums=(4,))
        self.start()

    # ------------------------------------------------------------------
    # Pool state
    # ------------------------------------------------------------------

    def _init_cache(self, batch: int):
        return self.api.init_cache(batch, self.max_seq,
                                   kv_dtype=self.kv_dtype,
                                   prefix_len=self.prefix_len,
                                   per_slot_scales=self.kv_dtype is not None)

    def _staging_row(self):
        """B=1 fp staging row for chunked admission. int8 pools stage fp
        too: finalize_staged_kv requantizes the finished row in one shot so
        the per-slot dequant scales calibrate over the WHOLE prompt, exactly
        like a blocking admission prefill."""
        if self.kv_dtype is None:
            return self._shard_cache(self._init_cache(1))
        row = self.api.init_cache(1, self.max_seq)
        if self.mesh is None:
            return row
        return jax.device_put(row, SH.cache_shardings(
            self.api.cache_roles(None), row, self.mesh))

    def _reset_pool(self) -> None:
        if self.paged:
            self._reset_pool_paged()
        else:
            self.cache = self._shard_cache(self._init_cache(self.n_slots))
            self.cushion_block = {}
        self.stats.pool_bytes = sum(
            x.nbytes for x in jax.tree_util.tree_leaves(
                (self.cache, self.cushion_block)))
        self.pos = jnp.zeros((self.n_slots,), jnp.int32)
        self.tok = jnp.zeros((self.n_slots,), jnp.int32)
        self.live = np.zeros((self.n_slots,), bool)
        self._slots = [_Slot() for _ in range(self.n_slots)]

    def _reset_pool_paged(self) -> None:
        """Build the paged pool: the dense (L, n_slots, max_seq, K, hd) KV
        leaves become a flat (L, n_pages, ps, K, hd) page store + an
        (L, n_slots, P) page table; every other leaf (int8 scales, hybrid's
        Mamba state) keeps its dense per-slot row. The fp cushion block is
        written ONCE here into batch-free kc/vc leaves — the refcounted,
        read-only cushion page every slot maps — and never copied again."""
        shapes = jax.eval_shape(lambda: self._init_cache(self.n_slots))
        ps = self.page_size
        pool = {}
        for key, sd in shapes.items():
            if key in self._paged_leaves:
                L, _, _, *rest = sd.shape
                pool[key] = jnp.zeros((L, self.n_pages, ps, *rest), sd.dtype)
            elif key not in ("kc", "vc"):
                pool[key] = jnp.zeros(sd.shape, sd.dtype)
        cu = {}
        if self.prefix_len:
            kvc = self.cushion["kv"]
            dt = (shapes["kc"].dtype if "kc" in shapes
                  else pool[self._paged_leaves[0]].dtype)
            cu = {"kc": jnp.asarray(kvc["k"]).astype(dt),
                  "vc": jnp.asarray(kvc["v"]).astype(dt)}
        self._pt_layers = int(pool[self._paged_leaves[0]].shape[0])
        self._pool = PagePool(self.n_slots, self.max_seq, ps, self.n_pages,
                              cushion_m=self.prefix_len,
                              prefix_cache=self._prefix_cache)
        pool["page_table"] = jnp.zeros(
            (self._pt_layers, self.n_slots, self._P), jnp.int32)
        self._pool.dirty = False            # device table == host (all 0)
        self.cache = self._shard_cache(pool, paged=True)
        # the shared cushion block lives OUTSIDE self.cache: it is never
        # passed through a donated jit, so these exact device buffers are
        # read (never copied, never consumed) by every decode step and
        # survive every admission/recycle — the "one refcounted, read-only
        # cushion page". PagePool.gauges() counts its logical refs.
        self.cushion_block = self._shard_cache(cu, paged=True)
        self._hpos = np.zeros((self.n_slots,), np.int64)

    def _shard_cache(self, cache, paged: bool = False):
        """Lay a pool (or B=1 admission row) out over the tp mesh along the
        family's cache_roles axes (heads / Mamba channels; see
        models/*.cache_roles). The admission row shares the pool's layout so
        the slot scatter is shard-local, never a reshard. The paged pool
        keeps the KV-heads axis of its page store on "M" (pages replace the
        batch/seq dims, heads stay sharded: (L, n_pages, ps, K, hd));
        the page table and the shared cushion block replicate."""
        if self.mesh is None:
            return cache
        roles = self.api.cache_roles(self.kv_dtype,
                                     per_slot_scales=self.kv_dtype is not None)
        if paged:
            roles = dict(roles)
            for key in self._paged_leaves:
                r = tuple(roles.get(key, ())) + (None,) * 5
                # (L,B,S,K,hd) role -> (L,n_pages,ps,K,hd): keep the layer
                # and heads/head-dim entries, pages/offsets replicate
                roles[key] = (r[0], None, None, r[3], r[4])
        return jax.device_put(cache, SH.cache_shardings(roles, cache,
                                                        self.mesh))

    def _sync_page_table(self) -> None:
        """Mirror the allocator's host table to the device pool, stacked
        over the layer axis (decode_step scans the cache layer-wise, so
        every pool leaf is L-leading; the table itself is identical per
        layer). Replicated under a mesh — page ids are layout metadata."""
        pt = np.broadcast_to(self._pool.table[None],
                             (self._pt_layers,) + self._pool.table.shape)
        arr = jnp.asarray(pt)
        if self.mesh is not None:
            arr = jax.device_put(arr, jax.sharding.NamedSharding(
                self.mesh, jax.sharding.PartitionSpec()))
        cache = dict(self.cache)
        cache["page_table"] = arr
        self.cache = cache
        self._pool.dirty = False
        self.stats.page_table_syncs += 1

    def _publish_gauges(self) -> None:
        g = self._pool.gauges()
        st = self.stats
        st.pages_total = g["pages_total"]
        st.pages_free = g["pages_free"]
        st.pages_shared = g["pages_shared"]
        st.cushion_page_refs = g["cushion_page_refs"]
        st.prefix_hits = self._pool.prefix_hits
        st.prefix_misses = self._pool.prefix_misses

    def _positions_needed(self, req: Request) -> int:
        S = req.batch["tokens"].shape[1]
        if "patches" in req.batch:
            S += req.batch["patches"].shape[1]
        return self.prefix_len + S + req.max_new_tokens

    # ------------------------------------------------------------------
    # Incremental serving API (the replica router's contract)
    # ------------------------------------------------------------------

    def start(self) -> None:
        """Open a serving session: reset the pool, the occupancy stats and
        the result buffers. Compiled executables are kept."""
        with SH.use_mesh(self.mesh):
            self._reset_pool()
        self.stats.reset()
        if self.paged:
            self._publish_gauges()
        self._results: Dict[int, RequestOutput] = {}
        self._ttft: Dict[int, float] = {}
        self._streams: collections.deque = collections.deque()
        self._expired: List[int] = []
        self._t0 = time.perf_counter()

    def now(self) -> float:
        """Seconds since ``start()`` (the session-relative clock every
        timestamp in ``RequestOutput`` is expressed in)."""
        return time.perf_counter() - self._t0

    def free_slots(self) -> List[int]:
        return [int(i) for i in np.flatnonzero(~self.live)
                if self._slots[i].req is None]

    @property
    def live_count(self) -> int:
        return int(self.live.sum())

    @property
    def prefilling(self) -> int:
        """Admission streams currently mid-prefill (PREFILLING slots). The
        router must keep stepping an engine whose only work is a stream."""
        return len(self._streams)

    def is_prefilling(self, uid: int) -> bool:
        """True while ``uid`` is a PREFILLING slot (partially-admitted).
        The engine itself enforces deadlines between chunks for these
        (``pop_expired``); the router leaves them out of its mid-decode
        deadline sweep so the rejection reason stays ``deadline-prefill``."""
        return any(st.req.uid == uid for st in self._streams)

    def pop_expired(self) -> List[int]:
        """Drain uids of streams retired between chunks for blowing their
        deadline (no result was produced; the router maps these to
        ``deadline-prefill`` rejections and clears its inflight entry)."""
        out, self._expired = self._expired, []
        return out

    def live_requests(self) -> List[Request]:
        """Requests currently occupying a slot — live decoders AND
        partially-prefilled streams (the router fails these over to
        surviving replicas when this engine dies)."""
        return [s.req for s in self._slots if s.req is not None]

    def try_admit(self, req: Request) -> bool:
        """Admit ``req`` into a free slot (B=1 prefill + full-row scatter,
        or the page scatter on a paged pool). Returns False when no slot is
        free — or, paged, when the page pool can't host the request right
        now — queueing and backpressure are the caller's job, the pool
        itself never buffers. Raises ValueError (and counts
        ``stats.positions_exhausted``) for a request whose prompt+budget
        can NEVER fit the pool: that's a permanent rejection, not
        backpressure.

        With ``chunk_tokens`` set (and a chunk-capable family), a prompt
        longer than one chunk budget starts a PREFILLING stream instead of
        prefilling here: the slot (and pages) are claimed now, the prompt
        is replayed one chunk per ``step()`` between decodes, and the pool
        admit happens at the final chunk — True means the request is this
        engine's responsibility either way."""
        free = self.free_slots()
        if not free:
            return False
        if (self.chunk_tokens is not None
                and self.api.supports_chunked_prefill
                and not ({"patches", "frames"} & set(req.batch))
                and req.batch["tokens"].shape[1] > self._chunk_budget()):
            return self._start_stream(req, free[0])
        return self._admit_request(req, free[0])

    def _chunk_budget(self) -> int:
        """Per-step prefill token budget. Fixed ``chunk_tokens`` unless
        auto mode: then it shrinks with decode pressure — every chunk
        stalls every live decoder for the chunk's prefill, so a near-full
        pool runs small chunks (protect TPOT) while an idle pool runs big
        ones (fewer interleave steps, better TTFT). Scales linearly from
        ``_AUTO_CHUNK_MAX`` at 0 live decoders to ``_AUTO_CHUNK_MIN`` at a
        full pool, bucketed to the same power-of-two executables as fixed
        budgets."""
        if not self.chunk_auto:
            return self.chunk_tokens
        pressure = float(self.live.sum()) / max(1, self.n_slots)
        want = int(round(_AUTO_CHUNK_MAX * (1.0 - pressure)))
        return bucket_steps(max(_AUTO_CHUNK_MIN, want))

    def step(self) -> List[int]:
        """Runs one prefill chunk of the oldest pending admission stream
        (chunked admission; no-op without streams), then one lock-step
        decode over the whole pool, retiring slots that hit EOS or budget.
        Returns the uids retired by the decode (their outputs are ready in
        ``pop_finished``). No-op when nothing is live or prefilling."""
        if self._streams:
            self._advance_stream()
        if not self.live.any():
            return []
        live_idx = np.flatnonzero(self.live)
        if self.paged:
            # map this step's write page for every live slot from its
            # admission reservation (lazy allocate-on-append), then mirror
            # any table change to the device before the kernel reads it
            for slot in live_idx:
                self._pool.ensure_mapped(int(slot), int(self._hpos[slot]))
            if self._pool.dirty:
                self._sync_page_table()
        with SH.use_mesh(self.mesh):
            self.tok, self.pos, self.cache = self._step(
                self.params, self.tok, self.pos, jnp.asarray(self.live),
                self.cache, self.cushion_block)
        if self.paged:
            self._hpos[live_idx] += 1   # mirror the device pos advance
        toks = np.asarray(self.tok)     # the one host sync per step
        self.stats.steps += 1
        self.stats.live_slot_steps += int(self.live.sum())
        retired: List[int] = []
        for slot in live_idx:
            s = self._slots[slot]
            req = s.req
            s.tokens.append(int(toks[slot]))
            if (len(s.tokens) >= req.max_new_tokens
                    or (req.eos_id is not None
                        and s.tokens[-1] == req.eos_id)):
                retired.append(req.uid)
                self._retire(int(slot))
        return retired

    def cancel(self, uid: int) -> bool:
        """Free the slot holding ``uid`` without producing a result
        (deadline expiry mid-decode, failover bookkeeping). The slot's
        stale KV needs no scrubbing: the next admission's full-row scatter
        overwrites it. A PREFILLING stream is dropped the same way (its
        staged row is discarded, its page reservation returned). Returns
        False if ``uid`` is not live here."""
        for st in self._streams:
            if st.req.uid == uid:
                self._streams.remove(st)
                self.stats.canceled += 1
                self._abort_stream(st, expired=False)
                return True
        for slot, s in enumerate(self._slots):
            if s.req is not None and s.req.uid == uid:
                self.live[slot] = False
                s.req = None
                self._ttft.pop(uid, None)
                self.stats.canceled += 1
                if self.paged:
                    # return the slot's pages; its frozen-pos dead writes
                    # land on the scratch page once the table row is zeroed
                    self._pool.release(slot)
                    self._publish_gauges()
                return True
        return False

    def pop_finished(self) -> List[RequestOutput]:
        """Drain completed outputs (uid-sorted) accumulated since the last
        call."""
        out = [self._results[u] for u in sorted(self._results)]
        self._results = {}
        return out

    # ------------------------------------------------------------------
    # Admission / retirement internals
    # ------------------------------------------------------------------

    def _check_capacity(self, req: Request) -> int:
        need = self._positions_needed(req)
        if self._seq_cache and need > self.max_seq:
            # permanent rejection (the request can NEVER fit this pool) —
            # counted explicitly instead of silently running out of
            # positions mid-decode. run() drops the request; the router
            # maps the raise to an "invalid" rejection, never a retry.
            self.stats.positions_exhausted += 1
            raise ValueError(
                f"request {req.uid} needs {need} positions "
                f"(prefix {self.prefix_len} + prompt + budget) "
                f"> pool max_seq {self.max_seq}")
        return need

    def _admit_request(self, req: Request, slot: int) -> bool:
        need = self._check_capacity(req)
        if self.paged:
            return self._admit_request_paged(req, slot, need)
        tpf = time.perf_counter()
        with SH.use_mesh(self.mesh):
            row = self._shard_cache(self._init_cache(1))
            logits, row, rpos = self._prefill(self.params, req.batch, row)
            logits = logits[:, -1] if logits.ndim == 3 else logits
            tok0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)[0]
            self.cache, self.pos, self.tok = self._admit(
                self.cache, row, jnp.asarray(slot, jnp.int32), self.pos,
                self.tok, rpos, tok0)
        first = int(jax.block_until_ready(tok0))
        self._book_admission(req, slot, first, tpf)
        return True

    def _admit_request_paged(self, req: Request, slot: int,
                             need: int) -> bool:
        """Paged admission: claim pages (full reservation — mid-decode
        exhaustion is impossible), prefill the B=1 row contiguously, then
        scatter each owned prompt page to its physical page. On a
        prefix-cache hit the donor's read-only stem pages are mapped
        (refcount++) and only the tail is prefilled against the extended
        cushion. Returns False (backpressure) when the page pool can't
        host the request right now."""
        prefill_end = need - req.max_new_tokens     # prefix + prompt
        tokens = None
        shared: List[int] = []
        if (self._prefix_cache
                and not ({"patches", "frames"} & set(req.batch))):
            tokens = np.asarray(req.batch["tokens"][0])
            shared = self._pool.lookup_stem(tokens)
        scatter = self._pool.admit(slot, prefill_end, need, shared=shared)
        if scatter is None:
            return False        # page-pool backpressure: retryable
        tpf = time.perf_counter()
        with SH.use_mesh(self.mesh):
            row = self._shard_cache(self._init_cache(1))
            if shared:
                # extended-cushion tail prefill: the donor's stem pages ARE
                # the stem's KV (bit-identical — stem hiddens depend only on
                # cushion+stem), so gather them once and prefill only the
                # uncovered tail at its true absolute positions
                stem_end = (self._pool.c0 + len(shared)) * self.page_size
                cu2 = self._stem_cushion(shared)
                t_skip = stem_end - self.prefix_len  # prompt tokens covered
                b2 = dict(req.batch)
                b2["tokens"] = req.batch["tokens"][:, t_skip:]
                logits, row, rpos = self._prefill_cu(self.params, b2, row,
                                                     cu2)
            else:
                logits, row, rpos = self._prefill(self.params, req.batch,
                                                  row)
            logits = logits[:, -1] if logits.ndim == 3 else logits
            tok0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)[0]
            self.cache, self.pos, self.tok = self._admit_paged(
                self.cache, row, jnp.asarray(slot, jnp.int32), self.pos,
                self.tok, rpos, tok0, jnp.asarray(scatter))
        first = int(jax.block_until_ready(tok0))
        if tokens is not None:
            self._pool.register_stem(slot, tokens, prefill_end)
        self._hpos[slot] = prefill_end
        self._book_admission(req, slot, first, tpf)
        self._publish_gauges()
        return True

    def _stem_cushion(self, shared: List[int]):
        """Extended cushion for a prefix-cache hit: the real cushion KV
        concatenated with the donor stem pages gathered from the page store
        (skipping the cushion rows that share the stem's first page)."""
        ps = self.page_size
        c0 = self._pool.c0
        donors = jnp.asarray(shared, jnp.int32)
        kp = self.cache["k"][:, donors]             # (L, h, ps, K, hd)
        vp = self.cache["v"][:, donors]
        kp = kp.reshape(kp.shape[0], -1, *kp.shape[3:])
        vp = vp.reshape(vp.shape[0], -1, *vp.shape[3:])
        skip = self.prefix_len - c0 * ps            # cushion rows in page c0
        if self.prefix_len:
            kvc = self.cushion["kv"]
            return {"kv": {
                "k": jnp.concatenate(
                    [jnp.asarray(kvc["k"], kp.dtype), kp[:, skip:]], axis=1),
                "v": jnp.concatenate(
                    [jnp.asarray(kvc["v"], vp.dtype), vp[:, skip:]], axis=1)}}
        return {"kv": {"k": kp, "v": vp}}

    # ------------------------------------------------------------------
    # Chunked admission (PREFILLING streams)
    # ------------------------------------------------------------------

    def _start_stream(self, req: Request, slot: int) -> bool:
        """Claim a slot (and, paged, the full page reservation — admission
        backpressure is decided up front, exactly like blocking) and queue
        the prompt for chunk-by-chunk prefill. Nothing touches the pool
        until the final chunk's admit scatter."""
        need = self._check_capacity(req)
        prefill_end = need - req.max_new_tokens     # prefix + prompt
        scatter = None
        shared: List[int] = []
        stem_tokens = None
        if self.paged:
            if self._prefix_cache:
                stem_tokens = np.asarray(req.batch["tokens"][0])
                shared = self._pool.lookup_stem(stem_tokens)
            scatter = self._pool.admit(slot, prefill_end, need, shared=shared)
            if scatter is None:
                return False    # page-pool backpressure: retryable
        toks = req.batch["tokens"]
        base = self.prefix_len
        if shared:
            # donor pages cover the stem; only the uncovered tail is chunked
            base = (self._pool.c0 + len(shared)) * self.page_size
            toks = toks[:, base - self.prefix_len:]
        with SH.use_mesh(self.mesh):
            row = self._staging_row()
        self._slots[slot].req = req     # PREFILLING: slot held, not live
        self._streams.append(_PrefillStream(req, slot, row, toks, base,
                                            shared, scatter, stem_tokens,
                                            prefill_end))
        return True

    def _advance_stream(self) -> None:
        """Run ONE prefill chunk (the per-step token budget) of the oldest
        pending stream, round-robin across streams so short prompts aren't
        head-of-line blocked behind a long one; finalize when the prompt is
        exhausted. Deadlines are enforced between chunks: an expired stream
        frees its slot (and pages) without a result."""
        st = self._streams.popleft()
        req = st.req
        if req.deadline_s is not None and self.now() > req.deadline_s:
            self._abort_stream(st, expired=True)
            return
        c = min(self._chunk_budget(), st.total - st.done)
        chunk = st.toks[:, st.done:st.done + c]
        with SH.use_mesh(self.mesh):
            if st.done == 0:
                b0 = dict(req.batch)
                b0["tokens"] = chunk
                if st.shared:
                    st.logits, st.row, st.rpos = self._prefill_cu(
                        self.params, b0, st.row, self._stem_cushion(st.shared))
                else:
                    st.logits, st.row, st.rpos = self._prefill(
                        self.params, b0, st.row)
            else:
                st.logits, st.row, st.rpos = self._prefill_re(
                    self.params, {"tokens": chunk}, st.row,
                    st.base + st.done)
        st.done += c
        self.stats.prefill_chunks += 1
        if st.done < st.total:
            self._streams.append(st)
        else:
            self._finalize_stream(st)

    def _finalize_stream(self, st: _PrefillStream) -> None:
        """Admit the finished staging row into the pool — the SAME admit
        scatter (and, int8, the same whole-prompt scale calibration) as the
        blocking path, so chunked and blocking admissions are
        token-for-token identical from the pool's point of view."""
        req, slot = st.req, st.slot
        with SH.use_mesh(self.mesh):
            logits = st.logits[:, -1] if st.logits.ndim == 3 else st.logits
            tok0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)[0]
            row = st.row
            if self.kv_dtype is not None:
                row = self._finalize_int8(row, st.total)
            sl = jnp.asarray(slot, jnp.int32)
            if self.paged:
                self.cache, self.pos, self.tok = self._admit_paged(
                    self.cache, row, sl, self.pos, self.tok, st.rpos, tok0,
                    jnp.asarray(st.scatter))
            else:
                self.cache, self.pos, self.tok = self._admit(
                    self.cache, row, sl, self.pos, self.tok, st.rpos, tok0)
        first = int(jax.block_until_ready(tok0))
        if st.stem_tokens is not None:
            self._pool.register_stem(slot, st.stem_tokens, st.prefill_end)
        if self.paged:
            self._hpos[slot] = st.prefill_end
        self._book_admission(req, slot, first, st.tpf)
        if self.paged:
            self._publish_gauges()

    def _abort_stream(self, st: _PrefillStream, expired: bool) -> None:
        """Drop a PREFILLING stream without a result (deadline blown
        between chunks, cancel, drain): free the slot, return the page
        reservation, discard the staged row."""
        self._slots[st.slot].req = None
        if self.paged:
            self._pool.release(st.slot)
            self._publish_gauges()
        if expired:
            self.stats.deadline_prefill += 1
            self._expired.append(st.req.uid)

    def _book_admission(self, req: Request, slot: int, first: int,
                        tpf: float) -> None:
        now = time.perf_counter()

        s = self._slots[slot]
        if s.used:
            self.stats.recycles += 1
        s.used = True
        s.req = req
        s.tokens = [first]
        s.t_admit = now - self._t0
        s.t_first = now
        self.stats.admitted += 1
        ttft = (now - tpf) * 1e3
        self._ttft[req.uid] = ttft
        done = (req.max_new_tokens <= 1
                or (req.eos_id is not None and first == req.eos_id))
        self.live[slot] = not done
        if done:
            self._retire(slot)

    def _retire(self, slot: int) -> None:
        s = self._slots[slot]
        req = s.req
        assert req is not None
        now = time.perf_counter()
        n = len(s.tokens)
        tpot = 0.0 if n <= 1 else (now - s.t_first) * 1e3 / (n - 1)
        self._results[req.uid] = RequestOutput(
            uid=req.uid, tokens=np.asarray(s.tokens, np.int32),
            ttft_ms=self._ttft[req.uid], tpot_ms=tpot, slot=slot,
            admitted_s=s.t_admit, finished_s=now - self._t0,
            latency_s=(now - self._t0) - req.arrival_s)
        self.live[slot] = False
        s.req = None
        self.stats.finished += 1
        if self.paged:
            # retirement RETURNS pages (free list + refcount decrements on
            # shared donors) instead of re-writing anything; the zeroed
            # table row routes the dead row's frozen-pos writes to scratch
            self._pool.release(slot)
            self._publish_gauges()

    # ------------------------------------------------------------------
    # Trace replay
    # ------------------------------------------------------------------

    def run(self, requests: Sequence[Request]) -> List[RequestOutput]:
        """Replay a trace: admit each request once its arrival time passes
        and a slot is free (FIFO), decode the pool in lock-step, return
        outputs sorted by uid. Re-entrant: the pool and the occupancy
        stats are reset per run (compiled executables are kept).

        ``KeyboardInterrupt`` (ctrl-C / the launcher's SIGTERM handler)
        triggers a graceful drain instead of dying mid-step: admission
        stops, live slots decode to completion, the queued remainder is
        dropped, and the completed outputs are returned with
        ``stats.interrupted`` set. A second interrupt aborts immediately."""
        self.start()
        queue = collections.deque(
            sorted(requests, key=lambda r: (r.arrival_s, r.uid)))
        done: Dict[int, RequestOutput] = {}
        draining = False

        while queue or self.live.any() or self._streams:
            try:
                if draining:
                    # partial admissions are unfinished work, dropped like
                    # the queued remainder (their slots/pages come back)
                    while self._streams:
                        self._abort_stream(self._streams.popleft(),
                                           expired=False)
                    if not self.live.any():
                        break
                else:
                    now = self.now()
                    # admit every arrived request that fits a free slot;
                    # requests that can NEVER fit (prompt+budget > capacity)
                    # are rejected outright — counted in
                    # stats.positions_exhausted, absent from the results —
                    # instead of crashing the whole trace
                    while queue and queue[0].arrival_s <= now:
                        try:
                            if not self.try_admit(queue[0]):
                                break
                        except ValueError:
                            queue.popleft()
                            continue
                        queue.popleft()
                    if not self.live.any() and not self._streams:
                        if queue:   # pool idle, next arrival in the future
                            time.sleep(min(1e-3, max(
                                0.0, queue[0].arrival_s - self.now())))
                        for o in self.pop_finished():
                            done[o.uid] = o
                        continue
                self.step()
                for o in self.pop_finished():
                    done[o.uid] = o
            except KeyboardInterrupt:
                if draining:
                    raise               # second interrupt: stop for real
                draining = True
                self.stats.interrupted = True

        for o in self.pop_finished():
            done[o.uid] = o
        return [done[u] for u in sorted(done)]
