"""Fault-tolerant multi-replica serving router.

``ReplicaRouter`` fronts N data-parallel ``ContinuousEngine`` replicas —
independent engines on one host for tests, or one engine per
``launch.mesh.make_replica_meshes`` device group (the ``data`` axis of the
serving mesh) in production — and owns everything the single-engine
scheduler deliberately does not:

* **Least-loaded dispatch.** A single bounded admission queue feeds
  replicas as slots free up; among candidates with capacity, HEALTHY
  replicas are preferred over DEGRADED ones, then fewest live slots wins.
  DEAD replicas are never dispatched.
* **Health tracking.** Each replica carries a
  ``distributed.fault_tolerance.HealthTracker``: heartbeat age,
  consecutive-error count and straggler detection fold into
  HEALTHY / DEGRADED / DEAD. A crash (``InjectedFault`` or any engine
  exception classified as fatal) marks the replica DEAD immediately; a
  corrupted heartbeat gets there via heartbeat-age timeout.
* **Backpressure.** The admission queue is bounded: when arrivals outrun
  the slot pools, new submissions get an explicit ``Rejected("queue_full")``
  instead of unbounded buffering. Deadline expiry is rejected from the
  queue (``deadline-queued``) or cancels the live slot
  (``deadline-decoding``).
* **Retry with capped exponential backoff.** A request on a dying replica
  is failed over: canceled on the dead engine, re-enqueued with
  ``backoff_base_s * 2**(attempts-1)`` (capped) and re-admitted on a
  survivor — from scratch, which is *bit-identical* by construction: the
  cushion/sink prefix KV is the same fp block on every replica
  (KVSink/IntactKV), and greedy decode is batch-composition independent,
  so a retried request reproduces the exact tokens the no-fault run
  produces. The chaos suite (tests/test_router.py, ``router_bench``)
  asserts this token-for-token.
* **Graceful drain.** ``KeyboardInterrupt`` (ctrl-C, or the launcher's
  SIGTERM handler) stops admission — queued and unarrived requests are
  rejected with reason ``draining`` — finishes every live slot, then
  returns the completed outputs with ``stats.drained`` set.
* **AllReplicasDead.** When every replica is DEAD and non-rejected work
  remains, the router raises instead of spinning forever.

Fault injection: pass a ``distributed.fault_injection.FaultInjector`` to
``run`` and the router fires the sites ``replica{i}.step`` /
``replica{i}.admit`` around every unit of replica work — crash, stall and
heartbeat-corruption schedules are deterministic, so failure-path tests
compare token streams, not vibes.

Single-threaded by design: replicas are stepped round-robin in one event
loop, which keeps the chaos schedules reproducible and the failover logic
free of locking. Throughput still scales with replicas because each step
decodes a whole slot pool; on real multi-device meshes the per-replica
steps are independent device programs.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import QuantConfig
from repro.distributed.fault_injection import FaultInjector, InjectedFault
from repro.distributed.fault_tolerance import (DEAD, DEGRADED, HEALTHY,
                                               HealthTracker)
from repro.models.registry import ModelAPI
from repro.monitoring import RouterStats, ServeStats
from repro.serving.engine import plan_quantization
from repro.serving.scheduler import ContinuousEngine, Request


class AllReplicasDead(RuntimeError):
    """Every replica is DEAD while non-rejected requests remain."""


@dataclasses.dataclass
class RouterConfig:
    """Router policy knobs (see module docstring for semantics)."""
    max_queue: int = 64             # bounded admission queue (new submits)
    max_retries: int = 2            # extra attempts after the first
    backoff_base_s: float = 0.02    # retry backoff: base * 2**(attempts-1)
    backoff_cap_s: float = 0.5
    heartbeat_timeout_s: float = 30.0
    dead_after_errors: int = 3      # consecutive errors -> DEAD
    straggler_factor: float = 3.0
    straggler_history: int = 8      # steps before the detector arms


@dataclasses.dataclass
class Rejected:
    """Explicit non-service outcome: backpressure (``queue_full``),
    deadline expiry (``deadline-queued`` / ``deadline-decoding``), retry
    exhaustion (``retries_exhausted``), shutdown (``draining``) or an
    invalid request (``invalid``)."""
    uid: int
    reason: str


@dataclasses.dataclass
class RoutedOutput:
    """A completed request as the router saw it: the engine's tokens and
    latency split plus which replica served it and how many admission
    attempts (1 = no retry) it took."""
    uid: int
    tokens: np.ndarray
    ttft_ms: float
    tpot_ms: float
    replica: int
    slot: int
    attempts: int
    latency_s: float
    finished_s: float


@dataclasses.dataclass
class RouterResult:
    outputs: List[RoutedOutput]     # uid-sorted completed requests
    rejected: List[Rejected]
    stats: RouterStats


@dataclasses.dataclass
class _QEntry:
    req: Request
    attempts: int = 0               # admissions attempted so far
    not_before: float = 0.0         # backoff gate (router clock)


class _Replica:
    def __init__(self, idx: int, engine: ContinuousEngine,
                 cfg: RouterConfig):
        self.idx = idx
        self.engine = engine
        self.cfg = cfg
        self.reset()

    def reset(self) -> None:
        """Fresh health state for a new serving session (``run`` resets
        every replica, so a replica killed in one trace replay serves the
        next — each run models an independent deployment)."""
        self.health = HealthTracker(
            heartbeat_timeout_s=self.cfg.heartbeat_timeout_s,
            dead_after_errors=self.cfg.dead_after_errors,
            straggler_factor=self.cfg.straggler_factor,
            min_history=self.cfg.straggler_history)
        self.heartbeat_suppressed = False   # chaos: corrupted heartbeat
        self.dead_handled = False           # failover ran for this death

    def state(self, now: float) -> str:
        return self.health.state(now)


class ReplicaRouter:
    """Multi-replica front-end over ``ContinuousEngine`` (see module
    docstring). Engine construction kwargs (``n_slots``, ``max_seq``,
    ``cushion``, ``kv_dtype``, ...) pass through; the quantization plan
    (``plan_quantization``) runs ONCE here so every replica serves the
    same calibrated scales and (optionally prequantized) weights.

    ``meshes``: optional per-replica device meshes
    (``launch.mesh.make_replica_meshes`` — the ``data``-axis groups);
    ``None`` builds every replica on the default device (CPU tests).

    ``paged=True`` (with ``page_size``/``n_pages``/``prefix_cache``) rides
    through like any engine kwarg: replicas share the quantization plan but
    each owns its page pool, page table and prefix-cache registry — page
    exhaustion in one replica backpressures like a full slot pool and the
    router retries elsewhere, while an over-capacity request raises at
    admission and is rejected as invalid (``positions_exhausted``)."""

    def __init__(self, api: ModelAPI, params, qcfg: QuantConfig,
                 n_replicas: int = 2, cfg: Optional[RouterConfig] = None,
                 stats: Optional[RouterStats] = None,
                 meshes: Optional[Sequence[Any]] = None,
                 cushion=None, scales=None, calib_batches=None,
                 prequant: bool = False, weight_bits: int = 8,
                 **engine_kwargs):
        if n_replicas < 1:
            raise ValueError("n_replicas must be >= 1")
        if meshes is not None and len(meshes) != n_replicas:
            raise ValueError(f"got {len(meshes)} meshes for "
                             f"{n_replicas} replicas")
        self.cfg = cfg if cfg is not None else RouterConfig()
        self.stats = stats if stats is not None else RouterStats()
        # one shared plan: calibrate/prequantize once, replicate everywhere
        params, scales = plan_quantization(
            api, params, qcfg, cushion=cushion, scales=scales,
            calib_batches=calib_batches, prequant=prequant,
            weight_bits=weight_bits)
        self.replicas = [
            _Replica(i, ContinuousEngine(
                api, params, qcfg, cushion=cushion, scales=scales,
                mesh=None if meshes is None else meshes[i],
                stats=ServeStats(), **engine_kwargs), self.cfg)
            for i in range(n_replicas)]
        self._queue: collections.deque = collections.deque()
        self._inflight: Dict[int, Tuple[_QEntry, _Replica]] = {}
        self._draining = False
        self._t0 = time.perf_counter()

    # ------------------------------------------------------------------
    # Clock / bookkeeping helpers
    # ------------------------------------------------------------------

    def now(self) -> float:
        return time.perf_counter() - self._t0

    def states(self, now: Optional[float] = None) -> List[str]:
        now = self.now() if now is None else now
        return [r.state(now) for r in self.replicas]

    def _all_dead(self, now: float) -> bool:
        return all(r.state(now) == DEAD for r in self.replicas)

    def _snapshot_stats(self, now: float) -> None:
        self.stats.n_replicas = len(self.replicas)
        self.stats.per_replica = [
            {"replica": r.idx, "state": r.state(now),
             "consecutive_errors": r.health.consecutive_errors,
             "heartbeat_age_s": r.health.heartbeat_age(now),
             "stragglers": len(r.health.stragglers),
             **r.engine.stats.as_dict()}
            for r in self.replicas]

    # ------------------------------------------------------------------
    # Admission queue (bounded; backpressure)
    # ------------------------------------------------------------------

    def submit(self, req: Request, now: Optional[float] = None
               ) -> Optional[Rejected]:
        """Accept ``req`` into the bounded admission queue, or return an
        explicit ``Rejected`` (queue full / draining / already past its
        deadline). The bound applies to *new* submissions only — failover
        requeues always fit, so a replica death never drops work that was
        already accepted."""
        now = self.now() if now is None else now
        if self._draining:
            return self._reject(req.uid, "draining")
        if req.deadline_s is not None and now > req.deadline_s:
            return self._reject(req.uid, "deadline-queued")
        if len(self._queue) >= self.cfg.max_queue:
            return self._reject(req.uid, "queue_full")
        self._queue.append(_QEntry(req=req))
        self.stats.submitted += 1
        self.stats.queue_depth_peak = max(self.stats.queue_depth_peak,
                                          len(self._queue))
        return None

    def _reject(self, uid: int, reason: str) -> Rejected:
        self.stats.reject(reason)
        return Rejected(uid=uid, reason=reason)

    def _requeue(self, entry: _QEntry, now: float) -> Optional[Rejected]:
        """Re-enqueue after a failed attempt, with capped exponential
        backoff; rejects once the retry budget is exhausted."""
        if entry.attempts > self.cfg.max_retries:
            return self._reject(entry.req.uid, "retries_exhausted")
        self.stats.retries += 1
        entry.not_before = now + min(
            self.cfg.backoff_cap_s,
            self.cfg.backoff_base_s * 2 ** max(0, entry.attempts - 1))
        self._queue.append(entry)
        self.stats.queue_depth_peak = max(self.stats.queue_depth_peak,
                                          len(self._queue))
        return None

    # ------------------------------------------------------------------
    # Replica lifecycle
    # ------------------------------------------------------------------

    def _kill_replica(self, rep: _Replica, now: float, reason: str,
                      rejected: List[Rejected],
                      outputs: Dict[int, RoutedOutput]) -> None:
        """Terminal transition: mark DEAD, harvest results it already
        finished, fail its live requests over to the queue."""
        if rep.dead_handled:
            return
        rep.health.mark_dead(reason)
        rep.dead_handled = True
        self.stats.replica_deaths += 1
        self._collect_replica(rep, now, outputs)    # finished work is valid
        self._harvest_expired(rep, rejected)        # so are its expirations
        for req in list(rep.engine.live_requests()):
            entry, _ = self._inflight.pop(req.uid, (None, None))
            rep.engine.cancel(req.uid)
            if entry is None:       # defensive: untracked live request
                entry = _QEntry(req=req, attempts=1)
            self.stats.failovers += 1
            rej = self._requeue(entry, now)
            if rej is not None:
                rejected.append(rej)

    def _pick_replica(self, now: float) -> Optional[_Replica]:
        """Least-loaded dispatch: HEALTHY replicas with a free slot first,
        DEGRADED only when no healthy peer has capacity, DEAD never."""
        ranked: List[Tuple[int, int, int, _Replica]] = []
        for rep in self.replicas:
            st = rep.state(now)
            if st == DEAD or not rep.engine.free_slots():
                continue
            ranked.append((0 if st == HEALTHY else 1,
                           rep.engine.live_count + rep.engine.prefilling,
                           rep.idx, rep))
        return min(ranked)[3] if ranked else None

    # ------------------------------------------------------------------
    # Event-loop stages
    # ------------------------------------------------------------------

    def _dispatch(self, now: float, injector: Optional[FaultInjector],
                  rejected: List[Rejected],
                  outputs: Dict[int, RoutedOutput]) -> None:
        i = 0
        while i < len(self._queue):
            entry = self._queue[i]
            if (entry.req.deadline_s is not None
                    and now > entry.req.deadline_s):
                del self._queue[i]
                rejected.append(self._reject(entry.req.uid,
                                             "deadline-queued"))
                continue
            if entry.not_before > now:      # backing off; try later ones
                i += 1
                continue
            rep = self._pick_replica(now)
            if rep is None:                 # no capacity anywhere
                break
            del self._queue[i]
            self._admit_on(rep, entry, now, injector, rejected, outputs)

    def _admit_on(self, rep: _Replica, entry: _QEntry, now: float,
                  injector: Optional[FaultInjector],
                  rejected: List[Rejected],
                  outputs: Dict[int, RoutedOutput]) -> None:
        entry.attempts += 1
        try:
            if injector is not None:
                for act in injector.fire(f"replica{rep.idx}.admit"):
                    if act == "heartbeat":
                        rep.heartbeat_suppressed = True
            ok = rep.engine.try_admit(entry.req)
        except KeyboardInterrupt:
            raise
        except InjectedFault as e:
            self._kill_replica(rep, now, str(e), rejected, outputs)
            rej = self._requeue(entry, now)
            if rej is not None:
                rejected.append(rej)
            return
        except ValueError as e:
            # request-shaped failure (e.g. needs more positions than the
            # pool holds) — retrying elsewhere cannot help
            rejected.append(self._reject(entry.req.uid, f"invalid: {e}"))
            return
        except Exception as e:  # noqa: BLE001 — replica-side failure
            rep.health.record_error(now)
            rej = self._requeue(entry, now)
            if rej is not None:
                rejected.append(rej)
            return
        if not ok:                          # raced out of the free slot
            entry.attempts -= 1
            self._queue.appendleft(entry)
            return
        self._inflight[entry.req.uid] = (entry, rep)

    def _step_replica(self, rep: _Replica, now: float,
                      injector: Optional[FaultInjector],
                      rejected: List[Rejected],
                      outputs: Dict[int, RoutedOutput]) -> None:
        t0 = time.perf_counter()
        try:
            if injector is not None:
                for act in injector.fire(f"replica{rep.idx}.step"):
                    if act == "heartbeat":
                        rep.heartbeat_suppressed = True
            rep.engine.step()
        except KeyboardInterrupt:
            raise
        except InjectedFault as e:
            self._kill_replica(rep, now, str(e), rejected, outputs)
            return
        except Exception as e:  # noqa: BLE001 — decode-step failure
            rep.health.record_error(now)
            if rep.state(now) == DEAD:
                self._kill_replica(rep, now, f"step failed: {e}",
                                   rejected, outputs)
            return
        dt = time.perf_counter() - t0
        rep.health.record_step(dt, now + dt,
                               beat=not rep.heartbeat_suppressed)

    def _expire_live(self, now: float, rejected: List[Rejected]) -> None:
        """Cancel live requests whose deadline passed mid-decode.
        PREFILLING streams are left alone: the engine enforces their
        deadline between chunks itself, and ``_harvest_expired`` maps those
        to ``deadline-prefill`` so the rejection reason says which phase
        blew the budget."""
        for uid in list(self._inflight):
            entry, rep = self._inflight[uid]
            if (entry.req.deadline_s is not None
                    and now > entry.req.deadline_s):
                if rep.engine.is_prefilling(uid):
                    continue
                if rep.engine.cancel(uid):      # still decoding: cut it
                    del self._inflight[uid]
                    rejected.append(self._reject(uid, "deadline-decoding"))
                # else: already finished, result collected normally

    def _harvest_expired(self, rep: _Replica,
                         rejected: List[Rejected]) -> None:
        """Collect uids the engine retired *between prefill chunks* for
        blowing their deadline (chunked admission). No result exists;
        clearing the inflight entry here is what lets ``run()`` terminate."""
        for uid in rep.engine.pop_expired():
            self._inflight.pop(uid, None)
            rejected.append(self._reject(uid, "deadline-prefill"))

    def _collect_replica(self, rep: _Replica, now: float,
                         outputs: Dict[int, RoutedOutput]) -> None:
        for o in rep.engine.pop_finished():
            entry, _ = self._inflight.pop(o.uid, (None, None))
            attempts = entry.attempts if entry is not None else 1
            arrival = entry.req.arrival_s if entry is not None else 0.0
            outputs[o.uid] = RoutedOutput(
                uid=o.uid, tokens=o.tokens, ttft_ms=o.ttft_ms,
                tpot_ms=o.tpot_ms, replica=rep.idx, slot=o.slot,
                attempts=attempts, latency_s=now - arrival, finished_s=now)
            self.stats.completed += 1

    def _live_total(self) -> int:
        return sum(r.engine.live_count for r in self.replicas)

    # ------------------------------------------------------------------
    # Trace replay
    # ------------------------------------------------------------------

    def run(self, requests: Sequence[Request],
            injector: Optional[FaultInjector] = None) -> RouterResult:
        """Replay a trace through the replica set. Returns every completed
        output (uid-sorted), the explicit rejections, and the router
        counters with per-replica health/occupancy snapshots. Raises
        ``AllReplicasDead`` when no replica survives while non-rejected
        work remains. ``KeyboardInterrupt`` drains gracefully (see module
        docstring)."""
        self.stats.reset()
        self._queue.clear()
        self._inflight.clear()
        self._draining = False
        for rep in self.replicas:
            rep.reset()
            rep.engine.start()
        self._t0 = time.perf_counter()
        pending = collections.deque(
            sorted(requests, key=lambda r: (r.arrival_s, r.uid)))
        outputs: Dict[int, RoutedOutput] = {}
        rejected: List[Rejected] = []

        while pending or self._queue or self._inflight:
            try:
                now = self.now()
                if self._draining:
                    while pending:
                        rejected.append(self._reject(
                            pending.popleft().uid, "draining"))
                    while self._queue:
                        rejected.append(self._reject(
                            self._queue.popleft().req.uid, "draining"))
                else:
                    while pending and pending[0].arrival_s <= now:
                        rej = self.submit(pending.popleft(), now)
                        if rej is not None:
                            rejected.append(rej)
                    self._dispatch(now, injector, rejected, outputs)
                if self._all_dead(now):
                    if self._queue or pending or self._inflight:
                        self._snapshot_stats(now)
                        raise AllReplicasDead(
                            f"all {len(self.replicas)} replicas DEAD with "
                            f"{len(self._queue) + len(pending) + len(self._inflight)} "
                            f"request(s) outstanding")
                    break
                stepped = False
                for rep in self.replicas:
                    if rep.state(now) == DEAD:
                        # health-driven death (heartbeat timeout, error
                        # budget): run failover once
                        self._kill_replica(rep, now, rep.health.dead_reason
                                           or "health: " + rep.state(now),
                                           rejected, outputs)
                        continue
                    if rep.engine.live_count == 0 \
                            and rep.engine.prefilling == 0:
                        continue
                    self._step_replica(rep, now, injector, rejected, outputs)
                    stepped = True
                now = self.now()
                self._expire_live(now, rejected)
                for rep in self.replicas:
                    self._harvest_expired(rep, rejected)
                    self._collect_replica(rep, now, outputs)
                if not stepped and (pending or self._queue):
                    # idle: wait out backoff gates / future arrivals
                    time.sleep(1e-3)
            except KeyboardInterrupt:
                if self._draining:
                    raise               # second interrupt: stop for real
                self._draining = True
                self.stats.drained = True

        self._snapshot_stats(self.now())
        return RouterResult(
            outputs=[outputs[u] for u in sorted(outputs)],
            rejected=rejected, stats=self.stats)
