"""repro.serving — static-batch Engine and the continuous-batching
scheduler (ContinuousEngine: slot pool, per-row decode positions)."""
from repro.serving.engine import Engine, GenerationResult, bucket_steps
from repro.serving.scheduler import ContinuousEngine, Request, RequestOutput

__all__ = ["Engine", "GenerationResult", "bucket_steps",
           "ContinuousEngine", "Request", "RequestOutput"]
