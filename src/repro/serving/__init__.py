"""repro.serving — static-batch Engine, the continuous-batching scheduler
(ContinuousEngine: slot pool, per-row decode positions) and the
fault-tolerant multi-replica front-end (ReplicaRouter: health-tracked
replicas, bounded admission queue, retry/failover, graceful drain)."""
from repro.serving.engine import Engine, GenerationResult, bucket_steps
from repro.serving.router import (AllReplicasDead, Rejected, ReplicaRouter,
                                  RoutedOutput, RouterConfig, RouterResult)
from repro.serving.scheduler import ContinuousEngine, Request, RequestOutput

__all__ = ["Engine", "GenerationResult", "bucket_steps",
           "ContinuousEngine", "Request", "RequestOutput",
           "ReplicaRouter", "RouterConfig", "RouterResult", "RoutedOutput",
           "Rejected", "AllReplicasDead"]
