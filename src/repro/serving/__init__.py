"""repro.serving"""
