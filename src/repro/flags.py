"""Runtime switches for perf-iteration A/B comparisons (env-controlled so a
fresh process can lower the *pre-optimization* behaviour for honest
baselines; see EXPERIMENTS.md §Perf).
"""
import os

# chunkwise mLSTM chunk length; 0 disables chunking (quadratic parallel form)
MLSTM_CHUNK = int(os.environ.get("REPRO_MLSTM_CHUNK", "256"))

def force_host_device_count(n: int) -> None:
    """Append ``--xla_force_host_platform_device_count=n`` to XLA_FLAGS so a
    CPU host emulates an n-device mesh (serving --tp / the sharding tests).
    Must run before jax initializes — a no-op once jax is imported, when a
    count is already forced, or for n <= 1. Real accelerator backends
    ignore the flag. (This module is jax-free precisely so launchers can
    call this before their first jax import.)"""
    import sys
    if n <= 1 or "jax" in sys.modules:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        return
    os.environ["XLA_FLAGS"] = \
        f"{flags} --xla_force_host_platform_device_count={n}".strip()


# decode attention kernel routing: "auto" = Pallas split-KV flash-decode on
# TPU backends, jnp oracle elsewhere; "pallas" / "jnp" force either path
# (the forced Pallas path runs in interpret mode off-TPU — validation only).
DECODE_KERNEL = os.environ.get("REPRO_DECODE_KERNEL", "auto")

# int8 matmul kernel routing for the true-int8 serving path
# (core.quantization.true_int_dot / prequantized_int_dot): "auto" = the
# Pallas w8a8_matmul kernel on TPU backends, lax.dot_general elsewhere;
# "pallas" / "jnp" force either path (forced Pallas runs in interpret mode
# off-TPU — validation only).
W8A8_KERNEL = os.environ.get("REPRO_W8A8_KERNEL", "auto")

# int4-packed weight matmul routing for the W4A8 serving path
# (core.quantization._int4_matmul): "auto" = the Pallas w4a8_matmul kernel
# (unpack-in-VMEM) on TPU backends, exact grouped jnp product elsewhere;
# "pallas" / "jnp" force either path (forced Pallas runs in interpret mode
# off-TPU — validation only).
W4A8_KERNEL = os.environ.get("REPRO_W4A8_KERNEL", "auto")
