"""Runtime switches for perf-iteration A/B comparisons (env-controlled so a
fresh process can lower the *pre-optimization* behaviour for honest
baselines; see EXPERIMENTS.md §Perf).
"""
import os

# chunkwise mLSTM chunk length; 0 disables chunking (quadratic parallel form)
MLSTM_CHUNK = int(os.environ.get("REPRO_MLSTM_CHUNK", "256"))

# decode attention: keep KV-sequence axis sharded (split-KV / flash-decoding)
DECODE_SPLIT_KV = os.environ.get("REPRO_SPLIT_KV", "1") != "0"

# decode attention kernel routing: "auto" = Pallas split-KV flash-decode on
# TPU backends, jnp oracle elsewhere; "pallas" / "jnp" force either path
# (the forced Pallas path runs in interpret mode off-TPU — validation only).
DECODE_KERNEL = os.environ.get("REPRO_DECODE_KERNEL", "auto")
