"""Activation-outlier analysis (paper §6.1, Table 5 / Figure 2): order
statistics of activation magnitudes — top-1/2/3, top-10%, median — per layer
and for the input of the last transformer block.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import QuantConfig


def magnitude_stats(x: jax.Array, n_skip: int = 0) -> Dict[str, jax.Array]:
    """x: (B, S, D) activations -> {top1, top2, top3, top10pct, median}."""
    if n_skip:
        x = x[:, n_skip:]
    mags = jnp.abs(x.astype(jnp.float32)).reshape(-1)
    k = 3
    top3 = jax.lax.top_k(mags, k)[0]
    q90 = jnp.quantile(mags, 0.9)
    med = jnp.quantile(mags, 0.5)
    return {"top1": top3[0], "top2": top3[1], "top3": top3[2],
            "top10pct": q90, "median": med}


def activation_range_penalty(taps: Any) -> jax.Array:
    """Differentiable activation-range regularizer (the L_q term of
    prefix tuning's L = L_pred + λ·L_q, eq. 11): sum over every collected
    *quantization site* of the squared tensor absmax,
    ``max(amax, -amin)²`` — the quantity a per-tensor grid's step size is
    proportional to, so squeezing it directly narrows the deployed scales.

    `core.quantization.site_stats` builds amin/amax from plain jnp
    reductions over the token part of each site's input, so gradient flows
    from this penalty back through attention into the cushion KV — the
    prefix literally learns to absorb whatever widens a quantization grid
    downstream. Per-layer stacked (L,) leaves and scalar head leaves both
    reduce into one fp32 total.

    Only true sites (linear inputs — what `site_qerr` measures and what
    pt_static scales cover) count; the analysis-only residual-stream taps
    (`calibration.NON_SITES`: block_in/final_in) are excluded. They sit
    before the norms, carry the massive-activation pathology at ~10³× the
    site magnitudes, and are never quantized — penalizing them drowns out
    the actual quantization-range signal.
    """
    from repro.core.calibration import NON_SITES
    total = jnp.zeros((), jnp.float32)

    def visit(d):
        nonlocal total
        if not isinstance(d, dict):
            return
        if "amin" in d and "amax" in d:
            half = jnp.maximum(d["amax"].astype(jnp.float32),
                               -d["amin"].astype(jnp.float32))
            total = total + jnp.sum(jnp.square(half))
            return                      # a site dict: no nested sites below
        for k, v in d.items():
            if k in NON_SITES:
                continue
            visit(v)

    visit(taps)
    return total


def last_block_input_stats(api, params, batch, qcfg: QuantConfig,
                           cushion=None, n_skip: int = 0) -> Dict[str, float]:
    """Table-5 numbers: magnitude stats of the input to the LAST transformer
    block, via a forward that returns per-layer block_in taps."""
    _, taps = api.forward(params, batch, qcfg, cushion=cushion, collect=True,
                          n_skip=n_skip)
    bi = taps["layers"]["block_in"]
    # per-layer (L,) amax; the heavy stats need the tensor itself, so we use
    # the collected absmax_ch of the last layer for top-1 and channel stats
    last = jax.tree_util.tree_map(lambda a: a[-1], bi)
    ch = np.asarray(last["absmax_ch"])
    ch_sorted = np.sort(ch)[::-1]
    return {
        "top1": float(ch_sorted[0]),
        "top2": float(ch_sorted[1]) if ch.size > 1 else float("nan"),
        "top3": float(ch_sorted[2]) if ch.size > 2 else float("nan"),
        "top10pct": float(np.quantile(ch, 0.9)),
        "median": float(np.quantile(ch, 0.5)),
    }


def per_layer_top_stats(api, params, batch, qcfg: QuantConfig,
                        cushion=None, n_skip: int = 0):
    """Figure-2 numbers: per-layer top-1 (channel absmax) and an approximate
    median across channels of block inputs."""
    _, taps = api.forward(params, batch, qcfg, cushion=cushion, collect=True,
                          n_skip=n_skip)
    bi = taps["layers"]["block_in"]
    ch = np.asarray(bi["absmax_ch"])        # (L, D)
    out = []
    for l in range(ch.shape[0]):
        row = np.sort(ch[l])[::-1]
        out.append({"layer": l, "top1": float(row[0]),
                    "top2": float(row[1]), "top3": float(row[2]),
                    "median": float(np.quantile(ch[l], 0.5))})
    return out
