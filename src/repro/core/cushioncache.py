"""CushionCache (paper §4): discover a prefix KV cache that mitigates
activation outliers in subsequent tokens.

Two stages:
  1. `greedy_search`   — Algorithm 1: grow a hard-token prompt one token at a
     time, each chosen (over a candidate subset of the embedding table, by
     batched inference) to minimize L_q(t | p, p'), with early stopping at
     improvement ratio tau.
  2. `prefix_tune`     — quantization-aware prefix tuning: freeze the model,
     train the cushion KV block (the only trainable leaves) on
     L = L_pred + lambda * L_range (paper eq. 11; `core.outliers`'
     differentiable activation-range penalty as the regularizer) with a
     straight-through quantized forward and stop-grad quantizer
     parameters. Compile-once donated step, periodic metric host syncs,
     optional data-axis batch sharding — see the function docstring.

The searched prefix is converted to the deployment artifact with
`ModelAPI.extract_cushion` (KV for attention archs, recurrent state for
SSM/hybrid — see DESIGN.md §5).

Search fast path
----------------
`greedy_search` is a compile-once, device-resident implementation for
families with a pure attention-KV prefix artifact (dense/moe/vlm):

* the prefix is padded to ``ccfg.max_prefix_len`` and a live-length scalar
  is threaded through attention masking, so ONE compiled executable serves
  every iteration (the reference recompiles per appended token);
* the shared prefix is prefilled into a KV cache once per iteration
  (``ModelAPI.prefix_kv``) and every candidate is scored against the cached
  block (``ModelAPI.score_candidates``) — no O(N·m) prefix recompute;
* candidates are scored by ``lax.map`` over fixed-size chunks with an
  on-device argmin, so each iteration costs one host sync instead of
  ``n_candidates / chunk``.

`greedy_search_ref` keeps the original full-forward implementation: it is
the parity oracle for the fast path, the scorer for families whose prefix
artifact is not pure attention KV (ssm/hybrid/encdec — `greedy_search`
falls back to it automatically), and the baseline for
``benchmarks/run.py search_bench``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CushionConfig, QuantConfig
from repro.models import transformer as T

Params = Dict[str, Any]


def cushion_fingerprint(cushion: Optional[Params]) -> str:
    """Content fingerprint of a cushion artifact: sha256 over every leaf's
    path, dtype, shape and exact bytes (``"none"`` for no cushion).

    This is the provenance tie between a cushion and everything derived
    under it: `launch/tune.py` stamps it into the artifact manifest (load
    integrity), `calibration.CalibratedScales` carries the fingerprint of
    the cushion its pt_static scales were calibrated under, and
    `serving.engine.plan_quantization` hard-fails when the two diverge —
    static ranges describe ONE cushioned activation distribution and
    silently serve garbage under another.
    """
    if cushion is None:
        return "none"
    h = hashlib.sha256()
    flat, _ = jax.tree_util.tree_flatten_with_path(cushion)
    for kp, leaf in flat:
        a = np.asarray(leaf)
        h.update(jax.tree_util.keystr(kp).encode())
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# L_q evaluation
# ---------------------------------------------------------------------------

def make_qerr_fn(api, qcfg: QuantConfig, scales: Optional[Params] = None
                 ) -> Callable:
    """Returns jit'd fn(params, prefix_ids (m,), batch) -> L_q of the token
    part (scales for dynamic modes derived from the token part only —
    matching deployment, where prefix tokens never re-enter the linears)."""

    def f(params, prefix_ids, batch):
        m = prefix_ids.shape[0]
        _, taps = api.forward_with_token_prefix(
            params, prefix_ids, batch, qcfg, scales=scales, collect=True,
            n_skip=m, remat=False)
        return T.total_qerr(taps)

    return jax.jit(f)


def make_batched_qerr_fn(api, qcfg: QuantConfig,
                         scales: Optional[Params] = None) -> Callable:
    """fn(params, prefixes (N, m), batch) -> (N,) L_q per candidate prefix —
    the paper's 'batched inference' for the argmin over the embedding table.
    """
    def one(params, prefix_ids, batch):
        m = prefix_ids.shape[0]
        _, taps = api.forward_with_token_prefix(
            params, prefix_ids, batch, qcfg, scales=scales, collect=True,
            n_skip=m, remat=False)
        return T.total_qerr(taps)

    return jax.jit(jax.vmap(one, in_axes=(None, 0, None)))


# ---------------------------------------------------------------------------
# Stage 1: greedy prefix search (Algorithm 1)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SearchResult:
    prefix_ids: np.ndarray
    history: List[Dict[str, float]]
    wall_time_s: float


# always-included nonsemantic candidates (<bos>-like low ids); also the
# sizing basis for the fast path's fixed candidate-pool shape
SPECIAL_TOKENS = (0, 1, 2, 3, 10, 13, 32, 198)


def _specials(vocab_size: int, seed_tokens: Tuple[int, ...]) -> np.ndarray:
    s = np.unique(np.array(list(seed_tokens) + list(SPECIAL_TOKENS)))
    return s[s < vocab_size]


def candidate_pool(rng, vocab_size: int, n: int,
                   seed_tokens: Tuple[int, ...] = ()) -> np.ndarray:
    """Random subset of the embedding table + always-included nonsemantic
    candidates, standing in for the full-table argmin (eq. 9) at CPU
    scale."""
    n_rand = max(0, n - len(SPECIAL_TOKENS))
    cands = jax.random.choice(rng, vocab_size, (n_rand,), replace=False)
    specials = _specials(vocab_size, seed_tokens)
    return np.unique(np.concatenate([np.asarray(cands), specials]))


def greedy_search_ref(api, params, sample_fn: Callable[[int], Dict[str, Any]],
                      qcfg: QuantConfig, ccfg: CushionConfig, rng,
                      chunk: int = 16, verbose: bool = True) -> SearchResult:
    """Algorithm 1, reference implementation (full forward per candidate).

    sample_fn(i) -> calibration batch (batch 1, length n). Each iteration
    draws a fresh sample t ~ D, evaluates all candidates p' by batched
    inference, and appends the argmin if it improves L_q by the factor tau
    (eq. 10); stops otherwise or at max length.

    Every iteration recompiles both scorers (the prefix shape grows by one
    token) and pays a host round-trip per candidate chunk. Kept as the
    parity oracle / benchmark baseline for `greedy_search`, and as the
    scorer for families without KV-reuse support (ssm/hybrid/encdec).
    """
    t0 = time.time()
    qerr_fn = make_qerr_fn(api, qcfg)
    batched_fn = make_batched_qerr_fn(api, qcfg)
    prefix: List[int] = list(ccfg.seed_tokens)
    history: List[Dict[str, float]] = []

    it = 0
    while len(prefix) < ccfg.max_prefix_len:
        rng, k1, k2 = jax.random.split(rng, 3)
        batch = sample_fn(it)
        base_ids = jnp.asarray(prefix, jnp.int32)
        base_err = float(qerr_fn(params, base_ids, batch))

        cands = candidate_pool(k1, api.cfg.vocab_size, ccfg.n_candidates,
                               ccfg.seed_tokens)
        best_err, best_tok = np.inf, -1
        for s in range(0, len(cands), chunk):
            cs = cands[s:s + chunk]
            if len(cs) < chunk:   # pad to keep one compiled shape
                cs = np.concatenate([cs, np.repeat(cs[-1:], chunk - len(cs))])
            pref = jnp.concatenate(
                [jnp.broadcast_to(base_ids[None], (chunk, len(prefix))),
                 jnp.asarray(cs, jnp.int32)[:, None]], axis=1)
            errs = np.asarray(batched_fn(params, pref, batch))
            j = int(np.argmin(errs))
            if errs[j] < best_err:
                best_err, best_tok = float(errs[j]), int(cs[j])

        history.append({"iter": it, "len": len(prefix), "base_err": base_err,
                        "best_err": best_err, "best_tok": best_tok,
                        "ratio": best_err / max(base_err, 1e-30)})
        if verbose:
            print(f"[greedy] it={it} len={len(prefix)} L_q={base_err:.4g} "
                  f"-> {best_err:.4g} (tok={best_tok}, "
                  f"ratio={best_err / max(base_err, 1e-30):.3f})")
        if best_err > ccfg.tau * base_err:
            break                      # eq. (10) early stop
        prefix.append(best_tok)
        it += 1

    return SearchResult(prefix_ids=np.asarray(prefix, np.int32),
                        history=history, wall_time_s=time.time() - t0)


def _pool_pad_len(vocab_size: int, ccfg: CushionConfig, chunk: int) -> int:
    """Static upper bound on `candidate_pool`'s (variable) length, rounded
    up to a chunk multiple — the fixed shape the compile-once search step is
    built for."""
    cap = max(0, ccfg.n_candidates - len(SPECIAL_TOKENS)) \
        + len(_specials(vocab_size, ccfg.seed_tokens))
    return max(chunk, -(-cap // chunk) * chunk)


def make_search_step_fn(api, qcfg: QuantConfig,
                        scales: Optional[Params] = None) -> Callable:
    """One fused greedy-search iteration, jitted once for the whole search:

        step(params, padded_prefix (max_m,), live_len (), cands
             (n_chunks, chunk), batch) -> (base_err, best_err, best_tok)

    Prefills the shared (padded) prefix into a KV cache, computes the base
    L_q, scores every candidate chunk via `lax.map` over the vmapped
    KV-reuse scorer, and argmins on device — all shapes are independent of
    the live prefix length, so the executable compiles exactly once.
    """
    def step(params, padded_prefix, live_len, cands, batch):
        pkv = api.prefix_kv(params, padded_prefix, qcfg, scales=scales)
        base = api.prefix_qerr(params, pkv, live_len, batch, qcfg,
                               scales=scales)
        errs = jax.lax.map(
            lambda cs: api.score_candidates(params, pkv, live_len, cs,
                                            batch, qcfg, scales=scales),
            cands).reshape(-1)
        j = jnp.argmin(errs)
        return base, errs[j], cands.reshape(-1)[j]

    return jax.jit(step)


def greedy_search(api, params, sample_fn: Callable[[int], Dict[str, Any]],
                  qcfg: QuantConfig, ccfg: CushionConfig, rng,
                  chunk: int = 16, verbose: bool = True) -> SearchResult:
    """Algorithm 1, compile-once fast path (see module docstring).

    Produces the same candidate pools in the same order as
    `greedy_search_ref` (identical rng schedule), scores them via KV reuse,
    and delegates to the reference implementation for families without an
    attention-KV-only prefix artifact.
    """
    if not api.supports_kv_scoring:
        if verbose:
            print(f"[greedy] {api.cfg.family}: no KV-reuse scoring; "
                  "falling back to greedy_search_ref")
        return greedy_search_ref(api, params, sample_fn, qcfg, ccfg, rng,
                                 chunk=chunk, verbose=verbose)

    t0 = time.time()
    max_m = ccfg.max_prefix_len
    step_fn = make_search_step_fn(api, qcfg)
    n_pool = _pool_pad_len(api.cfg.vocab_size, ccfg, chunk)
    prefix: List[int] = list(ccfg.seed_tokens)
    padded = np.zeros((max_m,), np.int32)
    padded[:len(prefix)] = prefix
    history: List[Dict[str, float]] = []

    it = 0
    while len(prefix) < max_m:
        rng, k1, k2 = jax.random.split(rng, 3)
        batch = sample_fn(it)
        cands = candidate_pool(k1, api.cfg.vocab_size, ccfg.n_candidates,
                               ccfg.seed_tokens).astype(np.int32)
        # pad to the fixed pool size by repeating the tail candidate:
        # duplicates tie in L_q and argmin keeps the first occurrence, so
        # the winner matches the reference's strict-improvement scan.
        cands = np.concatenate(
            [cands, np.repeat(cands[-1:], n_pool - len(cands))])
        base, best, tok = step_fn(params, jnp.asarray(padded),
                                  np.int32(len(prefix)),
                                  jnp.asarray(cands.reshape(-1, chunk)),
                                  batch)
        base_err, best_err, best_tok = float(base), float(best), int(tok)

        history.append({"iter": it, "len": len(prefix), "base_err": base_err,
                        "best_err": best_err, "best_tok": best_tok,
                        "ratio": best_err / max(base_err, 1e-30)})
        if verbose:
            print(f"[greedy] it={it} len={len(prefix)} L_q={base_err:.4g} "
                  f"-> {best_err:.4g} (tok={best_tok}, "
                  f"ratio={best_err / max(base_err, 1e-30):.3f})")
        if best_err > ccfg.tau * base_err:
            break                      # eq. (10) early stop
        padded[len(prefix)] = best_tok
        prefix.append(best_tok)
        it += 1

    return SearchResult(prefix_ids=np.asarray(prefix, np.int32),
                        history=history, wall_time_s=time.time() - t0)


# ---------------------------------------------------------------------------
# Stage 2: quantization-aware prefix tuning (paper §4.2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TuneResult:
    cushion: Params
    log: List[Dict[str, float]]
    wall_time_s: float


def _partition_cushion(cushion0: Params):
    """(frozen path substrings, stop-grad wrapper) for a family's cushion
    tree. The paper tunes the cached prefix KV, so the "kv" kc/vc block is
    the only trainable subtree; anything alongside it (the hybrid family's
    recurrent "state" leaves) is frozen — stop_gradient in the loss plus
    the AdamW `frozen` mask keeps those leaves bit-identical through
    tuning. Families whose whole artifact is recurrent state (ssm: no "kv"
    key) train the full tree."""
    if "kv" not in cushion0:
        return (), lambda c: c
    frozen = tuple(k for k in cushion0 if k != "kv")
    if not frozen:
        return (), lambda c: c

    def stop_grad_frozen(c):
        return {k: (v if k == "kv"
                    else jax.tree_util.tree_map(jax.lax.stop_gradient, v))
                for k, v in c.items()}

    return frozen, stop_grad_frozen


def prefix_tune(api, params, cushion0: Params,
                batch_iter: Iterable[Dict[str, Any]],
                qcfg: QuantConfig, ccfg: CushionConfig,
                scales: Optional[Params] = None,
                mesh=None, verbose: bool = True) -> TuneResult:
    """Freeze the model; train the cushion KV on
    L = L_pred + lambda * L_range (eq. 11, with `core.outliers`'
    differentiable activation-range penalty as the quantization
    regularizer). The quantized forward uses straight-through estimation;
    quantizer scale/zero-points are stop-grad'ed inside the quantizers
    (fake_quant), matching Jacob et al. QAT practice as cited by the paper.

    Pipeline properties:

    * the step jits ONCE and DONATES both the cushion and the optimizer
      state — fixed shapes, no per-step buffer copies;
    * only the "kv" block trains (`_partition_cushion`): hybrid recurrent
      state leaves come out bit-identical, preserving the serving pools'
      cushion-rewrite guarantee;
    * per-step metrics stay on device; the log drains to host every
      ``ccfg.log_every`` steps through `monitoring.host_sync` (ONE
      blocking transfer per drain — `count_host_syncs` bounds it in
      tests), while still recording every step;
    * ``mesh=`` shards batches over the mesh's "data" axis with the
      cushion/optimizer state replicated, `shard_update_step`-style
      (the batch size must divide the data axis).
    """
    from repro import monitoring as MON
    from repro.core import outliers as OUT
    from repro.optim.adamw import AdamW, constant_lr

    t0 = time.time()
    frozen, stop_grad_frozen = _partition_cushion(cushion0)
    opt = AdamW(lr=constant_lr(ccfg.tune_lr), weight_decay=0.0,
                grad_clip=1.0, frozen=frozen)
    state = opt.init(cushion0)

    def loss(cush, batch):
        cush = stop_grad_frozen(cush)
        _, aux = api.loss_fn(params, batch, qcfg, scales=scales,
                             cushion=cush, collect=True, remat=False)
        reg = OUT.activation_range_penalty(aux["taps"])
        total = aux["ce"] + ccfg.lam * reg
        return total, {"ce": aux["ce"], "range": reg,
                       "qerr": aux.get("qerr", jnp.zeros(()))}

    def step(cush, state, batch):
        (l, aux), g = jax.value_and_grad(loss, has_aux=True)(cush, batch)
        cush, state, om = opt.update(g, state, cush)
        return cush, state, {"loss": l, **aux, "gnorm": om["grad_norm"]}

    # the donated step consumes its carry buffers, including the very first
    # ones — train on a private copy so the caller's cushion0 stays alive
    cushion = jax.tree_util.tree_map(jnp.array, cushion0)
    it = iter(batch_iter)
    try:
        first = next(it)
    except StopIteration:
        return TuneResult(cushion=cushion, log=[],
                          wall_time_s=time.time() - t0)

    if mesh is None:
        step_fn = jax.jit(step, donate_argnums=(0, 1))
    else:
        from repro.train.trainer import replicated_shardings, \
            shard_update_step
        c_sh = replicated_shardings(cushion0, mesh)
        o_sh = replicated_shardings(jax.eval_shape(opt.init, cushion0),
                                    mesh)
        step_fn = shard_update_step(step, mesh, c_sh, o_sh, first)
        cushion = jax.device_put(cushion, c_sh)
        state = jax.device_put(state, o_sh)

    log: List[Dict[str, float]] = []
    pending: List[Tuple[int, Dict[str, Any]]] = []
    log_every = max(1, int(getattr(ccfg, "log_every", 10)))
    print_every = max(1, ccfg.tune_steps // 10)

    def drain():
        if not pending:
            return
        fetched = MON.host_sync([m for _, m in pending])
        for (j, _), mv in zip(pending, fetched):
            rec = {k: float(v) for k, v in mv.items()}
            rec["step"] = j
            log.append(rec)
            if verbose and j % print_every == 0:
                print(f"[tune] step={j} loss={rec['loss']:.4f} "
                      f"ce={rec['ce']:.4f} range={rec['range']:.4g} "
                      f"L_q={rec['qerr']:.4g}")
        pending.clear()

    for i, batch in enumerate(itertools.chain([first], it)):
        if i >= ccfg.tune_steps:
            break
        cushion, state, m = step_fn(cushion, state, batch)
        pending.append((i, m))
        if len(pending) >= log_every:
            drain()
    drain()
    return TuneResult(cushion=cushion, log=log,
                      wall_time_s=time.time() - t0)


# ---------------------------------------------------------------------------
# End-to-end pipeline
# ---------------------------------------------------------------------------

def discover(api, params, sample_fn: Callable[[int], Dict[str, Any]],
             batch_iter: Iterable[Dict[str, Any]], qcfg: QuantConfig,
             ccfg: CushionConfig, rng, skip_tune: bool = False,
             mesh=None, verbose: bool = True):
    """greedy search -> extract KV/state -> quantization-aware tuning.
    Returns (cushion, SearchResult, TuneResult|None).

    The artifact keeps the dtype `extract_cushion` emits (the model's
    cache/compute dtype): a bf16 model gets a bf16 cushion, so the serving
    pools' bit-identical cushion-rewrite-on-recycle guarantee holds without
    casts. (An earlier version force-cast to fp32 here, which broke that
    guarantee for bf16 models; AdamW keeps fp32 moments internally and
    casts the update back per leaf, so tuning preserves the dtype too.)"""
    sr = greedy_search(api, params, sample_fn, qcfg, ccfg, rng,
                       verbose=verbose)
    prefix_ids = jnp.asarray(sr.prefix_ids, jnp.int32)
    if prefix_ids.size == 0:
        prefix_ids = jnp.asarray([0], jnp.int32)
    cushion = api.extract_cushion(params, prefix_ids, None, qcfg)
    if skip_tune:
        return cushion, sr, None
    tr = prefix_tune(api, params, cushion, batch_iter, qcfg, ccfg,
                     mesh=mesh, verbose=verbose)
    return tr.cushion, sr, tr
