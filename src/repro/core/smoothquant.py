"""SmoothQuant (Xiao et al. 2023) reparameterization: migrate activation
magnitude into the weights with per-channel factors

    s_j = max|X_j|^alpha / max|W_j|^(1-alpha)

so activations become flatter (easier to quantize per-tensor) while weights
absorb the outliers. The paper combines CushionCache with SmoothQuant-O1/2/3
(per-token / per-tensor-dynamic / per-tensor-static respectively — the O*
level is just the activation quantizer granularity, which we configure via
QuantConfig.mode).

Folding map (dense/llama-style blocks, the paper's models):
  site "qkv"    -> ln1.g    /= s,  wqkv rows    *= s
  site "mlp_in" -> ln2.g    /= s,  w_up/gate rows *= s
  site "down"   -> w_up cols /= s, w_down rows  *= s   (gated: h = silu(g)*up)
  site "o"      -> wqkv v-cols /= s (GQA-reduced), wo rows *= s

MoE expert weights fold identically with an extra leading expert axis.
Sites on recurrent mixers (mamba/xlstm) have no exact fold through the
nonlinearity and are left unsmoothed (documented in DESIGN.md).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import Family, ModelConfig

Params = Dict[str, Any]


def _factors(act_absmax_ch: jax.Array, w_absmax_ch: jax.Array,
             alpha: float) -> jax.Array:
    a = jnp.maximum(act_absmax_ch.astype(jnp.float32), 1e-5)
    w = jnp.maximum(w_absmax_ch.astype(jnp.float32), 1e-5)
    s = a ** alpha / w ** (1.0 - alpha)
    return jnp.clip(s, 1e-2, 1e4)


def _w_absmax_in(w: jax.Array) -> jax.Array:
    """Per-input-channel |W| max; w: (..., d_in, d_out) -> (d_in,)."""
    red = tuple(range(w.ndim - 2)) + (w.ndim - 1,)
    return jnp.max(jnp.abs(w.astype(jnp.float32)), axis=red)


def smooth_dense_layer(lp: Params, lstats: Dict[str, Any], cfg: ModelConfig,
                       alpha: float) -> Params:
    """Smooth one dense transformer layer. lp/lstats are single-layer
    (unstacked) pytrees; returns the updated layer params."""
    lp = jax.tree_util.tree_map(lambda a: a, lp)  # shallow copy
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = lp["attn"]["wqkv"].dtype

    # qkv <- ln1
    s = _factors(lstats["qkv"]["absmax_ch"], _w_absmax_in(lp["attn"]["wqkv"]),
                 alpha)
    lp["ln1"] = dict(lp["ln1"])
    lp["attn"] = dict(lp["attn"])
    lp["ln1"]["g"] = (lp["ln1"]["g"] / s.astype(dt))
    if "b" in lp["ln1"]:
        lp["ln1"]["b"] = lp["ln1"]["b"] / s.astype(dt)
    lp["attn"]["wqkv"] = lp["attn"]["wqkv"] * s[:, None].astype(dt)

    # o <- v columns of wqkv (GQA: o-input (H*hd) reduces to v channels (K*hd))
    so_full = lstats["o"]["absmax_ch"]                    # (H*hd,)
    so_v = jnp.max(so_full.reshape(K, H // K, hd), axis=1).reshape(K * hd)
    s = _factors(so_v, _w_absmax_in(lp["attn"]["wo"]).reshape(
        K, H // K, hd).max(axis=1).reshape(K * hd), alpha)
    vcols = lp["attn"]["wqkv"][:, (H + K) * hd:]
    lp["attn"]["wqkv"] = lp["attn"]["wqkv"].at[:, (H + K) * hd:].set(
        vcols / s.astype(dt))
    if "bqkv" in lp["attn"]:
        b = lp["attn"]["bqkv"]
        lp["attn"]["bqkv"] = b.at[(H + K) * hd:].set(
            b[(H + K) * hd:] / s.astype(dt))
    s_o = jnp.tile(s.reshape(K, 1, hd), (1, H // K, 1)).reshape(H * hd)
    lp["attn"]["wo"] = lp["attn"]["wo"] * s_o[:, None].astype(dt)

    # mlp_in <- ln2
    mlp = dict(lp["mlp"])
    s = _factors(lstats["mlp_in"]["absmax_ch"], _w_absmax_in(mlp["w_up"]),
                 alpha)
    lp["ln2"] = dict(lp["ln2"])
    lp["ln2"]["g"] = lp["ln2"]["g"] / s.astype(dt)
    if "b" in lp["ln2"]:
        lp["ln2"]["b"] = lp["ln2"]["b"] / s.astype(dt)
    mlp["w_up"] = mlp["w_up"] * s[:, None].astype(dt)
    if "w_gate" in mlp:
        mlp["w_gate"] = mlp["w_gate"] * s[:, None].astype(dt)

    # down <- w_up output columns
    s = _factors(lstats["down"]["absmax_ch"], _w_absmax_in(mlp["w_down"]),
                 alpha)
    mlp["w_up"] = mlp["w_up"] / s[None, :].astype(dt)
    mlp["w_down"] = mlp["w_down"] * s[:, None].astype(dt)
    lp["mlp"] = mlp
    return lp


def apply_smoothquant(params: Params, stats: Dict[str, Any],
                      cfg: ModelConfig, alpha: float = 0.8) -> Params:
    """Smooth all layers. `stats` is the merged calibration stats tree
    (leaves stacked (L, ...) over layers). Supported: DENSE/VLM fully;
    other families: the attention/mlp sites where present."""
    if cfg.family not in (Family.DENSE, Family.VLM):
        raise NotImplementedError(
            f"SmoothQuant folding implemented for dense-family archs; "
            f"{cfg.family} mixers have no exact fold (see DESIGN.md)")
    L = cfg.n_layers
    lstats = stats["layers"]

    def one(i):
        lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
        ls = jax.tree_util.tree_map(lambda a: a[i], lstats)
        return smooth_dense_layer(lp, ls, cfg, alpha)

    smoothed = [one(i) for i in range(L)]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *smoothed)
    out = dict(params)
    out["layers"] = stacked
    return out
