"""Quantization core (paper §3).

Implements linear (affine) quantization with the paper's configuration space:

* activations: asymmetric, with three granularities
    - ``pt_static``      per-tensor, static range (calibrated scales)
    - ``pt_dynamic``     per-tensor, range computed on the fly
    - ``ptoken_dynamic`` per-token, range computed on the fly
* weights: symmetric group-wise (group along the contracting dim)

Two execution paths:

* **fake-quant** (quantize->dequantize in float, straight-through gradients):
  used for fidelity experiments, calibration, the greedy search and the
  quantization-aware prefix tuning.
* **true-int8** (``lax.dot_general`` on int8 with ``preferred_element_type=
  int32`` and a fused scalar epilogue): the deployment/serving path, also
  what the Pallas ``w8a8_matmul`` kernel implements on TPU.

All functions are pure; static ranges live in a ``scales`` pytree threaded
through the model forward.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import QuantConfig

Array = jax.Array


# ---------------------------------------------------------------------------
# Quantization parameters (scale / zero-point), eq. (3)-(4)
# ---------------------------------------------------------------------------

def qrange(bits: int, symmetric: bool) -> Tuple[int, int]:
    if symmetric:
        return -(2 ** (bits - 1) - 1), 2 ** (bits - 1) - 1
    return 0, 2 ** bits - 1


def params_from_minmax(mn: Array, mx: Array, bits: int, symmetric: bool
                       ) -> Tuple[Array, Array]:
    """scale, zero_point from observed (min, max). Shapes broadcast."""
    qmin, qmax = qrange(bits, symmetric)
    if symmetric:
        amax = jnp.maximum(jnp.abs(mn), jnp.abs(mx))
        scale = amax / qmax
        zero = jnp.zeros_like(scale)
    else:
        mn = jnp.minimum(mn, 0.0)
        mx = jnp.maximum(mx, 0.0)
        scale = (mx - mn) / (qmax - qmin)
        zero = qmin - mn / jnp.where(scale == 0, 1.0, scale)
        zero = jnp.round(jnp.clip(zero, qmin, qmax))
    scale = jnp.where(scale <= 0, 1.0, scale)
    return scale, zero


def quantize(x: Array, scale: Array, zero: Array, bits: int,
             symmetric: bool) -> Array:
    qmin, qmax = qrange(bits, symmetric)
    return jnp.clip(jnp.round(x / scale + zero), qmin, qmax)


def dequantize(xq: Array, scale: Array, zero: Array) -> Array:
    return (xq - zero) * scale


def fake_quant(x: Array, scale: Array, zero: Array, bits: int,
               symmetric: bool) -> Array:
    """Quantize->dequantize with straight-through gradient (the rounding is
    invisible to autodiff; scale/zero receive no gradient — the paper's
    stop-grad on quantizer parameters)."""
    scale = jax.lax.stop_gradient(scale)
    zero = jax.lax.stop_gradient(zero)
    y = dequantize(quantize(x, scale, zero, bits, symmetric), scale, zero)
    y = y.astype(x.dtype)     # fp32 scales must not promote bf16 activations
    return x + jax.lax.stop_gradient(y - x)


# ---------------------------------------------------------------------------
# Activation quantization per granularity
# ---------------------------------------------------------------------------

def act_minmax(x: Array, per_token: bool) -> Tuple[Array, Array]:
    if per_token:
        mn = jnp.min(x, axis=-1, keepdims=True)
        mx = jnp.max(x, axis=-1, keepdims=True)
    else:
        mn = jnp.min(x)
        mx = jnp.max(x)
    return mn, mx


def act_fake_quant(x: Array, cfg: QuantConfig,
                   static_scale: Optional[Array] = None,
                   static_zero: Optional[Array] = None) -> Array:
    """Apply the configured activation quantizer (fake-quant path)."""
    if cfg.mode == "none":
        return x
    if cfg.mode == "pt_static":
        assert static_scale is not None, "static mode needs calibrated scales"
        return fake_quant(x, static_scale, static_zero, cfg.a_bits,
                          cfg.symmetric_a)
    per_token = cfg.mode == "ptoken_dynamic"
    mn, mx = act_minmax(jax.lax.stop_gradient(x), per_token)
    scale, zero = params_from_minmax(mn, mx, cfg.a_bits, cfg.symmetric_a)
    return fake_quant(x, scale, zero, cfg.a_bits, cfg.symmetric_a)


# ---------------------------------------------------------------------------
# Weight quantization: symmetric, group-wise along contracting dim
# ---------------------------------------------------------------------------

def weight_fake_quant(w: Array, cfg: QuantConfig) -> Array:
    """w: (..., d_in, d_out); groups tile the d_in (contracting) axis."""
    if cfg.mode == "none" and not cfg.true_int8:
        return w
    if cfg.w_bits >= 16:
        return w
    d_in = w.shape[-2]
    g = cfg.w_group if cfg.w_group and d_in % cfg.w_group == 0 else d_in
    shp = w.shape
    wg = w.reshape(*shp[:-2], d_in // g, g, shp[-1])
    amax = jnp.max(jnp.abs(wg), axis=-2, keepdims=True)
    scale, zero = params_from_minmax(-amax, amax, cfg.w_bits, True)
    wq = fake_quant(wg, scale, zero, cfg.w_bits, True)
    return wq.reshape(shp)


def weight_quant_int(w: Array, cfg: QuantConfig) -> Tuple[Array, Array]:
    """True-int path needs a single per-tensor weight scale so the dequant is
    one scalar multiply in the matmul epilogue (per-tensor static deployment).
    Returns (w_int8, scale).

    Sub-8-bit range convention: every quantizer here goes through ``qrange``,
    whose symmetric range is *restricted* — [-(2^(b-1)-1), 2^(b-1)-1], i.e.
    [-7, 7] at 4 bits, never the full two's-complement [-8, 7]. The int4
    packed format stores nibbles that could hold -8, but the quantizers never
    emit it; tests/test_quantization.py pins this so fake-quant calibration
    and true packed inference stay on the same grid."""
    amax = jnp.max(jnp.abs(w))
    scale, _ = params_from_minmax(-amax, amax, cfg.w_bits, True)
    wq = quantize(w, scale, jnp.zeros(()), cfg.w_bits, True).astype(jnp.int8)
    return wq, scale


def weight_quant_int4(w: Array, cfg: QuantConfig
                      ) -> Tuple[Array, Array, int]:
    """Group-wise symmetric int4 weight quantization (the W4A8 true path).

    Unlike ``weight_quant_int`` (per-tensor — fine at 8 bits), 4-bit needs
    the *same group-wise scales as* ``weight_fake_quant``: a single
    per-tensor scale loses too much range, and — the satellite-1 fix — a
    granularity mismatch between calibration (fake-quant, group-wise) and
    serving (true packed) would make the two paths disagree. Using the
    identical group/amax/scale computation makes
    ``dequant(unpack(pack(wq))) == weight_fake_quant(w)`` bit-for-bit.

    w: (d_in, d_out). Returns (wq, scale, group_size) with wq (d_in, d_out)
    int8 holding values in the restricted [-7, 7] range and scale
    (n_groups, d_out) fp32. Groups tile d_in; ``cfg.w_group`` is used when
    it divides d_in, else one group spans the whole axis (mirroring
    ``weight_fake_quant``)."""
    d_in, d_out = w.shape
    g = cfg.w_group if cfg.w_group and d_in % cfg.w_group == 0 else d_in
    wg = w.reshape(d_in // g, g, d_out)
    amax = jnp.max(jnp.abs(wg), axis=-2, keepdims=True)        # (G,1,N)
    scale, zero = params_from_minmax(-amax, amax, 4, True)
    wq = quantize(wg, scale, zero, 4, True).astype(jnp.int8)
    return wq.reshape(d_in, d_out), scale[:, 0, :], g


# ---------------------------------------------------------------------------
# int4 packing: two nibbles per byte along the contracting dim
# ---------------------------------------------------------------------------

def pack_int4(wq: Array) -> Array:
    """Pack int4 values (int8 storage, [-8, 7]) along axis 0, two per byte:
    element 2i lands in the LOW nibble of byte i, element 2i+1 in the HIGH
    nibble (interleaved layout — unpack is a stack+reshape, no shuffle).
    Odd-length axes get a zero nibble of padding; ``unpack_int4(p, k)``
    slices it back off. Returns int8 of shape (ceil(K/2), ...)."""
    K = wq.shape[0]
    if K % 2:
        wq = jnp.pad(wq, [(0, 1)] + [(0, 0)] * (wq.ndim - 1))
    lo = jax.lax.bitcast_convert_type(wq[0::2], jnp.uint8) & 0xF
    hi = jax.lax.bitcast_convert_type(wq[1::2], jnp.uint8) & 0xF
    return jax.lax.bitcast_convert_type(lo | (hi << 4), jnp.int8)


def unpack_int4(packed: Array, k: int) -> Array:
    """Inverse of ``pack_int4``: (ceil(k/2), ...) int8 -> (k, ...) int8 with
    sign-extended nibbles. Arithmetic shifts in int32 recover both nibbles:
    the low one via sign-extension from bit 3, the high one via
    floor-division (arithmetic >> 4 of the two's-complement byte)."""
    p = packed.astype(jnp.int32)
    lo = (p << 28) >> 28
    hi = p >> 4
    w = jnp.stack([lo, hi], axis=1)                  # (Kp, 2, ...)
    w = w.reshape(w.shape[0] * 2, *packed.shape[1:])
    return w[:k].astype(jnp.int8)


# ---------------------------------------------------------------------------
# Quantized linear
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SiteScale:
    """Calibrated static range for one activation site (pytree)."""
    scale: Array
    zero: Array


jax.tree_util.register_pytree_node(
    SiteScale,
    lambda s: ((s.scale, s.zero), None),
    lambda _, c: SiteScale(*c),
)


def _use_w8a8_kernel() -> bool:
    """Route int8 matmuls through the Pallas ``w8a8_matmul`` kernel? "auto"
    enables it on TPU backends only (lax.dot_general is the CPU oracle);
    "pallas" forces interpret-mode execution off-TPU (validation)."""
    from repro import flags
    if flags.W8A8_KERNEL == "pallas":
        return True
    if flags.W8A8_KERNEL == "jnp":
        return False
    return jax.default_backend() == "tpu"


def _use_w4a8_kernel() -> bool:
    """Same routing contract for the int4-packed ``w4a8_matmul`` kernel
    (REPRO_W4A8_KERNEL=auto|pallas|jnp)."""
    from repro import flags
    if flags.W4A8_KERNEL == "pallas":
        return True
    if flags.W4A8_KERNEL == "jnp":
        return False
    return jax.default_backend() == "tpu"


def _tile(n: int, target: int) -> int:
    """Largest power-of-two block <= target that divides n (weight dims are
    static per checkpoint; falls to 1 only for pathological odd dims)."""
    t = min(target, n)
    while n % t:
        t //= 2
    return max(t, 1)


_F32_EXACT_K = 1024  # 1024 * 128 * 128 == 2**24: f32 partial sums stay exact


def _int_product_f32_exact(xq: Array, w_int: Array) -> Array:
    """Bit-exact int8 x int8 -> int32 product for CPU backends.

    XLA:CPU scalarizes int8 ``dot_general`` (no int8 GEMM in Eigen), which
    made prequantized *prefill* ~4x slower than fp on the CPU bench. Casting
    to f32 routes the product through the vectorized f32 GEMM instead, and
    chunking the contraction at ``_F32_EXACT_K`` keeps it exact: every
    partial sum is bounded by 1024*128*128 = 2**24, the largest integer
    magnitude f32 represents exactly, so each chunk's f32 accumulation is
    integer-exact and the int32 chunk sum matches the int32 dot bit for
    bit."""
    K = w_int.shape[0]
    cdim = xq.ndim - 1
    xf = xq.astype(jnp.float32)
    wf = w_int.astype(jnp.float32)
    acc = None
    for k0 in range(0, K, _F32_EXACT_K):
        k1 = min(k0 + _F32_EXACT_K, K)
        part = jax.lax.dot_general(
            jax.lax.slice_in_dim(xf, k0, k1, axis=cdim),
            jax.lax.slice_in_dim(wf, k0, k1, axis=0),
            (((cdim,), (0,)), ((), ()))).astype(jnp.int32)
        acc = part if acc is None else acc + part
    return acc


def _int8_matmul(xq: Array, w_int: Array, s_x, z_x, s_w,
                 colsum: Array, out_dtype) -> Array:
    """Shared int8 x int8 epilogue-fused matmul behind ``true_int_dot`` and
    ``prequantized_int_dot``:

      (X_int - z) @ W_int * s_x s_w
        = (X_int @ W_int) * s_x s_w  -  z * colsum(W_int) * s_x s_w

    colsum(W_int) is precomputable per weight; it folds into one rank-1
    subtract (cheap, fuses). On TPU (or with REPRO_W8A8_KERNEL=pallas) the
    whole product+epilogue runs in the Pallas ``w8a8_matmul`` kernel —
    int8 MXU tiles with the scalar dequant fused in the kernel epilogue and
    ragged M padded/sliced inside the kernel wrapper — so every 2-D
    ``qlinear`` site (prefill *and* the jitted decode scan) hits the
    MXU-int8 fast path. Scalar (per-tensor static) scales only."""
    if _use_w8a8_kernel() and w_int.ndim == 2 and jnp.ndim(s_x) == 0:
        from repro.kernels.w8a8_matmul import w8a8_matmul
        K, N = w_int.shape
        lead = xq.shape[:-1]
        M = 1
        for d in lead:
            M *= d
        out = w8a8_matmul(
            xq.reshape(M, K), w_int, s_x, z_x, s_w, colsum=colsum,
            bm=256, bn=_tile(N, 512), bk=_tile(K, 256),
            interpret=jax.default_backend() != "tpu")
        return out.reshape(*lead, N).astype(out_dtype)
    if jax.default_backend() != "tpu":
        acc = _int_product_f32_exact(xq, w_int)
    else:
        acc = jax.lax.dot_general(
            xq, w_int, (((xq.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
    acc = acc.astype(jnp.float32) - jnp.asarray(z_x, jnp.float32) \
        * colsum.astype(jnp.float32)
    return (acc * (jnp.asarray(s_x, jnp.float32)
                   * jnp.asarray(s_w, jnp.float32))).astype(out_dtype)


def _int4_matmul(xq: Array, w_packed: Array, s_x, z_x, s_w,
                 colsum: Array, out_dtype) -> Array:
    """int8 activations x int4-packed weights with group-wise weight scales:

      out = s_x * ( sum_g s_w[g,:] * (X_int[:, g] @ W_int[g, :])
                    - z_x * colsum_scaled )

    where g ranges over contiguous groups of the contracting dim and
    ``colsum_scaled[n] = sum_g s_w[g,n] * colsum_g[n]`` is precomputed at
    prequantize time (the zero-point correction already carries the group
    scales, so the epilogue stays one rank-1 subtract exactly like W8A8).

    On TPU (or REPRO_W4A8_KERNEL=pallas) the unpack + product + epilogue run
    in the Pallas ``w4a8_matmul`` kernel — nibbles stream HBM->VMEM at
    0.5 byte/weight and are sign-extended in VMEM. The jnp fallback unpacks,
    folds the group scales into the weight columns once per call, and runs a
    single f32 GEMM — the same product shape as the W8A8 CPU path, so
    prefill TTFT stays in the fp ballpark (a grouped batched einsum was
    ~1.6x fp on the bench). Folding trades the grouped path's integer
    exactness for one extra f32 rounding per weight element (~1e-7
    relative); the kernel accumulates per-group like the grouped form, and
    the two routes agree to f32-accumulation tolerance, not bit-identically.
    """
    K = xq.shape[-1]
    G = s_w.shape[0]
    assert K % G == 0, f"groups ({G}) must tile the contracting dim ({K})"
    group = K // G
    N = w_packed.shape[-1]
    lead = xq.shape[:-1]
    if _use_w4a8_kernel() and w_packed.ndim == 2 and jnp.ndim(s_x) == 0:
        from repro.kernels.w4a8_matmul import w4a8_matmul
        M = 1
        for d in lead:
            M *= d
        out = w4a8_matmul(
            xq.reshape(M, K), w_packed, s_x, z_x, s_w, colsum,
            group_size=group, bm=256, bn=_tile(N, 512),
            interpret=jax.default_backend() != "tpu")
        return out.reshape(*lead, N).astype(out_dtype)
    wq = unpack_int4(w_packed, K)                          # (K, N) int8
    wdq = wq.astype(jnp.float32).reshape(G, group, N) \
        * s_w.astype(jnp.float32)[:, None, :]
    acc = jnp.einsum("...k,kn->...n", xq.astype(jnp.float32),
                     wdq.reshape(K, N))
    acc = acc - jnp.asarray(z_x, jnp.float32) * colsum.astype(jnp.float32)
    return (acc * jnp.asarray(s_x, jnp.float32)).astype(out_dtype)


def true_int_dot(x: Array, w: Array, cfg: QuantConfig,
                 site: Optional[SiteScale]) -> Array:
    """int8 x int8 -> int32 matmul with scalar-epilogue dequant (see
    ``_int8_matmul`` for the zero-point algebra and the Pallas routing).
    Weights are quantized on the fly (constant-folds under jit when ``w``
    is a weight); ``prequantized_int_dot`` is the int8-resident variant."""
    wq, s_w = weight_quant_int(w, cfg)
    if cfg.mode == "pt_static":
        assert site is not None
        s_x, z_x = site.scale, site.zero
    else:
        mn, mx = act_minmax(x, cfg.mode == "ptoken_dynamic")
        s_x, z_x = params_from_minmax(mn, mx, cfg.a_bits, cfg.symmetric_a)
    xq = quantize(x, s_x, z_x, cfg.a_bits, cfg.symmetric_a)
    if not cfg.symmetric_a:
        # asymmetric range is [0, 2^b-1]; offset by -2^(b-1) to store in
        # int8 and fold the offset into the zero-point correction
        off = 2 ** (cfg.a_bits - 1)
        xq = xq - off
        z_x = z_x - off
    xq = xq.astype(jnp.int8)
    colsum = jnp.sum(wq.astype(jnp.int32), axis=0)
    return _int8_matmul(xq, wq, s_x, z_x, s_w, colsum, x.dtype)


def prequantized_int_dot(x: Array, w: Dict[str, Array], cfg: QuantConfig,
                         site: Optional[SiteScale]) -> Array:
    """Serving path with int8-resident weights: HBM streams 1 byte/weight
    (2x less than bf16) straight into the int8 MXU matmul — no on-the-fly
    weight requantization, no bf16 dequant materialization. The stored
    colsum feeds the zero-point correction without re-reducing the weight.
    Requires calibrated static scales (``site``): per-tensor static W8A8 is
    the deployment configuration the CushionCache prefix rescues.

    Two resident formats, distinguished by key: ``w_int`` (int8, 1 B/weight)
    routes through ``_int8_matmul``; ``w_packed`` (int4 nibbles, 0.5
    B/weight, group-wise scales) through ``_int4_matmul``. Activations are
    int8 in both — W4A8 narrows the weights only."""
    if cfg.mode != "pt_static" or site is None:
        raise ValueError(
            "prequantized (int8-resident) weights serve the pt_static "
            "deployment path only and need calibrated site scales; got "
            f"mode={cfg.mode!r}, site={'set' if site is not None else None}")
    s_x, z_x = site.scale, site.zero
    xq = quantize(x, s_x, z_x, cfg.a_bits, cfg.symmetric_a)
    if not cfg.symmetric_a:
        off = 2 ** (cfg.a_bits - 1)
        xq = xq - off
        z_x = z_x - off
    xq = xq.astype(jnp.int8)
    if "w_packed" in w:
        return _int4_matmul(xq, w["w_packed"], s_x, z_x, w["w_scale"],
                            w["colsum"], x.dtype)
    return _int8_matmul(xq, w["w_int"], s_x, z_x, w["w_scale"],
                        w["colsum"], x.dtype)


def prequantize(w: Array, cfg: QuantConfig,
                weight_bits: int = 8) -> Dict[str, Array]:
    """Quantize one (d_in, d_out) weight into its resident serving dict.

    weight_bits=8: {"w_int" int8 (K,N), "w_scale" scalar, "colsum" (N,)
    int32} — per-tensor scale, raw column sums.
    weight_bits=4: {"w_packed" int8 (ceil(K/2),N) nibble-packed, "w_scale"
    (G,N) group-wise, "colsum" (N,) f32 *scaled* column sums
    sum_g s_w[g,n]*colsum_g[n]} — the scales ride in the colsum so the
    kernel epilogue stays a rank-1 subtract."""
    if weight_bits == 4:
        wq, scale, g = weight_quant_int4(w, cfg)
        G = w.shape[0] // g
        colsum_g = jnp.sum(
            wq.astype(jnp.int32).reshape(G, g, -1), axis=1)    # (G, N)
        colsum = jnp.sum(colsum_g.astype(jnp.float32) * scale, axis=0)
        return {"w_packed": pack_int4(wq), "w_scale": scale,
                "colsum": colsum}
    if weight_bits != 8:
        raise ValueError(f"weight_bits must be 8 or 4, got {weight_bits}")
    wq, scale = weight_quant_int(w, cfg)
    return {"w_int": wq, "w_scale": scale,
            "colsum": jnp.sum(wq.astype(jnp.int32), axis=0)}


_PREQUANT_KEYS = ("wqkv", "wo", "w_up", "w_gate", "w_down", "w_in", "w_out",
                  "w_proj")


def prequantize_tree(params: Any, cfg: QuantConfig,
                     min_ndim: int = 2, weight_bits: int = 8) -> Any:
    """Replace qdot-consumed weight matrices with int-resident Quantized
    dicts (int8 ``w_int`` or, with ``weight_bits=4``, nibble-packed
    ``w_packed``). Only keys consumed via `qlinear`/`qdot` are converted
    (MoE expert/gate projections consumed by raw einsums — and the Arctic
    dense residual branch living under the same ``moe`` subtree — keep fp);
    embeddings stay fp (gather lookups). Hybrid period params nest their
    sublayers in lists; those are descended too."""
    if weight_bits not in (8, 4):
        raise ValueError(f"weight_bits must be 8 or 4, got {weight_bits}")

    def eligible(k, v, path):
        if not (hasattr(v, "ndim") and v.ndim >= min_ndim):
            return False
        if "embed" in path or "moe" in path:
            return False
        if k in _PREQUANT_KEYS:
            return True
        return k == "w" and path and path[-1] == "head"

    def convert(v):
        if v.ndim == 2:
            return prequantize(v, cfg, weight_bits=weight_bits)
        # stacked over layers/periods: quantize per layer slice
        if weight_bits == 4:
            return jax.vmap(
                lambda a: prequantize(a, cfg, weight_bits=4))(v)
        wq, scale = jax.vmap(lambda a: weight_quant_int(a, cfg))(v)
        return {"w_int": wq, "w_scale": scale,
                "colsum": jnp.sum(wq.astype(jnp.int32), axis=-2)}

    def visit(d, path=()):
        out = {}
        for k, v in d.items():
            if isinstance(v, dict):
                out[k] = visit(v, path + (k,))
            elif isinstance(v, (list, tuple)):
                out[k] = [visit(e, path + (k, i)) if isinstance(e, dict)
                          else e for i, e in enumerate(v)]
            elif eligible(k, v, path):
                out[k] = convert(v)
            else:
                out[k] = v
        return out
    return visit(params)


def qdot(x: Array, w: Any, cfg: QuantConfig,
         site: Optional[SiteScale] = None) -> Array:
    """Quantized x @ w. ``w`` is (d_in, d_out) / (..., d_in, d_out), or a
    prequantized {"w_int" | "w_packed", "w_scale", "colsum"} dict."""
    if isinstance(w, dict):
        return prequantized_int_dot(x, w, cfg, site)
    if cfg.mode == "none":
        return x @ w
    if cfg.true_int8 and w.ndim == 2 and cfg.a_bits == 8 and cfg.w_bits == 8:
        return true_int_dot(x, w, cfg, site)
    xq = act_fake_quant(x, cfg,
                        site.scale if site is not None else None,
                        site.zero if site is not None else None)
    wq = weight_fake_quant(w, cfg)
    return xq @ wq


# ---------------------------------------------------------------------------
# Quantization error L_q, eq. (6), + site statistics for calibration/analysis
# ---------------------------------------------------------------------------

def site_qerr(x: Array, cfg: QuantConfig, site: Optional[SiteScale],
              n_skip: int = 0) -> Array:
    """||X - q(X)||^2 over the token part (positions >= n_skip along axis -2).

    For dynamic modes the scale is derived from the same (token-part) tensor,
    mirroring deployment; for static mode the calibrated scale is used.
    """
    if n_skip:
        x = x[..., n_skip:, :]
    # NOTE: qerr stays differentiable w.r.t. x (prefix-tuning needs the
    # gradient); only the quantizer parameters are stop-grad'ed below.
    if cfg.mode == "pt_static" and site is not None:
        scale, zero = site.scale, site.zero
    else:
        per_token = cfg.mode == "ptoken_dynamic"
        mn, mx = act_minmax(jax.lax.stop_gradient(x), per_token)
        scale, zero = params_from_minmax(mn, mx, cfg.a_bits, cfg.symmetric_a)
    scale = jax.lax.stop_gradient(scale)
    zero = jax.lax.stop_gradient(zero)
    xq = dequantize(quantize(x, scale, zero, cfg.a_bits, cfg.symmetric_a),
                    scale, zero)
    return jnp.sum(jnp.square((x - xq).astype(jnp.float32)))


def site_stats(x: Array, n_skip: int = 0) -> Dict[str, Array]:
    """Reduced statistics for calibration & Table-5-style analysis."""
    if n_skip:
        x = x[..., n_skip:, :]
    xf = x.astype(jnp.float32)
    return {
        "amin": jnp.min(xf),
        "amax": jnp.max(xf),
        "absmax_ch": jnp.max(jnp.abs(xf), axis=tuple(range(x.ndim - 1))),
    }


def scales_from_stats(stats: Any, cfg: QuantConfig) -> Any:
    """Turn a pytree of {amin, amax, absmax_ch} leaves (one dict per site)
    into a pytree of SiteScale for pt_static deployment."""
    def one(site: Dict[str, Array]) -> SiteScale:
        scale, zero = params_from_minmax(site["amin"], site["amax"],
                                         cfg.a_bits, cfg.symmetric_a)
        return SiteScale(scale=scale, zero=zero)
    is_site = lambda d: isinstance(d, dict) and "amin" in d
    return jax.tree_util.tree_map(one, stats, is_leaf=is_site)


def merge_stats(a: Any, b: Any) -> Any:
    """Running union of two stats pytrees (min of mins, max of maxes)."""
    if a is None:
        return b

    def one(sa, sb):
        return {"amin": jnp.minimum(sa["amin"], sb["amin"]),
                "amax": jnp.maximum(sa["amax"], sb["amax"]),
                "absmax_ch": jnp.maximum(sa["absmax_ch"], sb["absmax_ch"])}
    is_site = lambda d: isinstance(d, dict) and "amin" in d
    return jax.tree_util.tree_map(one, a, b, is_leaf=is_site)
