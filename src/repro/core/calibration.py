"""Static-range calibration: run instrumented forwards over a calibration
set, merge activation statistics, and derive per-site static scales
(paper §5.1: "for static range quantization, we calibrate using the training
split").
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import Family, QuantConfig
from repro.core import quantization as Q

NON_SITES = ("block_in", "final_in")


class CalibratedScales(NamedTuple):
    """Static scales plus the fingerprint of the cushion they were
    calibrated under (`cushioncache.cushion_fingerprint`; ``"none"`` for a
    cushionless calibration). `serving.engine.plan_quantization` unwraps
    this and hard-fails when handed a different cushion — pt_static ranges
    describe one cushioned activation distribution and silently serve
    garbage under another. Produced by `calibrate_tagged` and by
    `launch/serve.py` when loading a tune artifact's saved scales."""
    scales: Any
    cushion_fp: str


def calibrate_tagged(api, params, batches: Iterable[Dict[str, Any]],
                     qcfg: QuantConfig, cushion=None, n_skip: int = 0):
    """`calibrate`, with the scales wrapped in their cushion provenance.
    Returns (CalibratedScales, merged_stats)."""
    from repro.core.cushioncache import cushion_fingerprint
    scales, merged = calibrate(api, params, batches, qcfg, cushion=cushion,
                               n_skip=n_skip)
    return CalibratedScales(scales, cushion_fingerprint(cushion)), merged


def scales_to_plain(scales: Any) -> Any:
    """SiteScale leaves -> plain ``{"scale", "zero"}`` dicts, so a scales
    pytree can ride a `checkpoint.store` artifact as nested dicts."""
    return jax.tree_util.tree_map(
        lambda s: {"scale": s.scale, "zero": s.zero}, scales,
        is_leaf=lambda x: isinstance(x, Q.SiteScale))


def scales_from_plain(tree: Any) -> Any:
    """Inverse of `scales_to_plain` (restored leaves may be numpy)."""
    is_site = lambda d: isinstance(d, dict) and set(d) == {"scale", "zero"}
    return jax.tree_util.tree_map(
        lambda d: Q.SiteScale(scale=jnp.asarray(d["scale"]),
                              zero=jnp.asarray(d["zero"])),
        tree, is_leaf=is_site)


def _sites_only(tree: Dict[str, Any]) -> Dict[str, Any]:
    return {k: v for k, v in tree.items() if k not in NON_SITES}


def taps_to_stats(taps: Dict[str, Any]) -> Dict[str, Any]:
    """Strip non-site entries from a taps tree, keep {amin, amax, absmax_ch}."""
    out: Dict[str, Any] = {}
    if "layers" in taps:
        out["layers"] = _sites_only(taps["layers"])
    if "enc_layers" in taps:
        out["enc_layers"] = _sites_only(taps["enc_layers"])
    if "head" in taps:
        out["head"] = taps["head"]
    def clean(site):
        return {"amin": site["amin"], "amax": site["amax"],
                "absmax_ch": site["absmax_ch"]}
    is_site = lambda d: isinstance(d, dict) and "amin" in d
    return jax.tree_util.tree_map(clean, out, is_leaf=is_site)


def stats_to_scales(stats: Dict[str, Any], qcfg: QuantConfig,
                    family: Family) -> Dict[str, Any]:
    """Scales pytree in the layout the model forwards expect:
      dense-like: {site: SiteScale(L,), ..., "head": SiteScale()}
      encdec:     {"enc": {...}, "dec": {...}, "head": SiteScale()}
    """
    conv = lambda tree: Q.scales_from_stats(tree, qcfg)
    if family == Family.ENCDEC:
        out = {"enc": conv(stats["enc_layers"]),
               "dec": conv(stats["layers"])}
    else:
        out = conv(stats["layers"])
    if "head" in stats:
        out["head"] = conv({"head": stats["head"]})["head"]
    return out


def calibrate(api, params, batches: Iterable[Dict[str, Any]],
              qcfg: QuantConfig, cushion=None, n_skip: int = 0
              ) -> Dict[str, Any]:
    """Collect stats over `batches` and return the static scales pytree.

    When a cushion is supplied the statistics describe the *cushioned*
    activation distribution — scales must always be calibrated for the
    deployment configuration (paper: scales determined for t_{1:n} only).
    """
    import dataclasses
    merged: Optional[Dict[str, Any]] = None
    # Stats describe the FP model: collection pass runs unquantized compute.
    obs_cfg = dataclasses.replace(qcfg, mode="none")
    collect = jax.jit(lambda p, b: api.forward(
        p, b, obs_cfg, cushion=cushion, collect=True, n_skip=n_skip)[1])
    for batch in batches:
        taps = collect(params, batch)
        merged = Q.merge_stats(merged, taps_to_stats(taps))
    assert merged is not None, "empty calibration set"
    return stats_to_scales(merged, qcfg, api.cfg.family), merged
