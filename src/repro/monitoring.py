"""Compile-count and serving-occupancy instrumentation.

Compile counting is built on ``jax.monitoring`` events.

XLA emits a ``/jax/core/compile/backend_compile_duration`` event per backend
compilation. The absolute multiplier per ``jit`` cache miss is a jax-version
detail (helper executables also compile), but the count is deterministic for
a fixed program, which is all the search/bench assertions need: *constant*
compile count independent of prefix length, and fast-path count « reference
count.

Usage::

    with count_compiles() as c:
        run_search(...)
    print(c.count)

Counters nest (each active counter sees every compile event), so a bench can
hold an outer counter while tests open inner ones.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, Iterator, List

import jax

COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

_active: List["CompileCounter"] = []
_registered = False


@dataclasses.dataclass
class CompileCounter:
    count: int = 0


def _listener(event: str, duration: float, **kwargs) -> None:
    if event == COMPILE_EVENT:
        for c in _active:
            c.count += 1


@contextlib.contextmanager
def count_compiles() -> Iterator[CompileCounter]:
    """Count backend compilations that happen inside the ``with`` block.

    The listener registers once per process (jax.monitoring has no
    unregister API across versions); counters activate/deactivate via the
    ``_active`` stack instead.
    """
    global _registered
    if not _registered:
        jax.monitoring.register_event_duration_secs_listener(_listener)
        _registered = True
    c = CompileCounter()
    _active.append(c)
    try:
        yield c
    finally:
        _active.remove(c)


@dataclasses.dataclass
class HostSyncCounter:
    count: int = 0


_sync_active: List["HostSyncCounter"] = []


@contextlib.contextmanager
def count_host_syncs() -> Iterator[HostSyncCounter]:
    """Count blocking device->host transfers routed through `host_sync`
    inside the ``with`` block. jax.monitoring has no transfer event, so
    accounting works by convention: host-loop code that must block on
    device values (the prefix-tuning metric drain) fetches them through
    `host_sync` instead of calling ``float(...)`` / ``np.asarray`` per
    value, and regression tests bound the count. Counters nest like
    `count_compiles`."""
    c = HostSyncCounter()
    _sync_active.append(c)
    try:
        yield c
    finally:
        _sync_active.remove(c)


def host_sync(tree):
    """THE accounting choke point for intentional blocking transfers:
    one call = one device->host round trip (``jax.device_get`` fetches the
    whole tree in a single batch). Dispatch-blocking per-step ``float(v)``
    conversions were the original prefix_tune perf bug — anything tempted
    to sync in a loop should batch values and come through here."""
    for c in _sync_active:
        c.count += 1
    return jax.device_get(tree)


def resident_weight_bytes(params) -> tuple:
    """(fp_bytes, int8_bytes, int4_bytes) of a served parameter tree — how
    many bytes per weight the decode loop streams from HBM. A prequantized
    tree (core.quantization.prequantize_tree) holds its qdot-consumed
    matrices as int8 ``w_int`` leaves (1 byte/weight vs 2-4 for bf16/fp32)
    or nibble-packed int8 ``w_packed`` leaves (0.5 byte/weight, counted by
    their packed size); everything else (embeddings, norms, scales, MoE
    experts) counts as fp. Surfaced in ``ServeStats`` and printed by
    launch/serve.py so the fp/W8A8/W4A8 A/B shows its memory side, not just
    TTFT/TPOT."""
    fp = i8 = i4 = 0
    leaves, _ = jax.tree_util.tree_flatten_with_path(params)
    for path, leaf in leaves:
        if not hasattr(leaf, "dtype"):
            continue
        n = int(leaf.size) * leaf.dtype.itemsize
        if path and "w_packed" in str(path[-1]):
            i4 += n
        elif str(leaf.dtype) == "int8":
            i8 += n
        else:
            fp += n
    return fp, i8, i4


@dataclasses.dataclass
class ServeStats:
    """Continuous-batching scheduler counters (serving/scheduler.py).

    ``steps`` counts lock-step decode iterations over the slot pool;
    ``live_slot_steps`` accumulates how many of the pool's slots held a live
    request at each step, so ``occupancy()`` is the mean fraction of decode
    compute spent on real tokens (1.0 = perfectly packed, low values =
    the pool idles between arrivals). Retired/empty slots still run
    (compute-masked, outputs discarded) — occupancy is the serve bench's
    measure of that waste.

    ``weight_bytes_fp`` / ``weight_bytes_int8`` record the resident served
    parameter bytes by storage precision (``resident_weight_bytes``) —
    configuration facts set at engine load, preserved across ``reset()``.

    ``canceled`` counts live slots freed without a result (deadline expiry
    mid-decode, router failover bookkeeping); ``interrupted`` records that
    the run ended via the graceful-drain path (ctrl-C / SIGTERM) rather
    than trace exhaustion.

    Page-pool gauges (zero on contiguous pools): ``pages_total`` /
    ``pool_bytes`` are layout facts set at pool construction (preserved
    across ``reset()`` like the weight bytes); ``pages_free`` /
    ``pages_shared`` / ``cushion_page_refs`` mirror the allocator after
    every admission/retirement (shared = refcount > 1, i.e. prefix-cache
    donor pages and registry pins; cushion refs = the pool's pinned
    reference + one per live slot mapping the shared cushion block).
    ``prefix_hits`` / ``prefix_misses`` count prefix-cache lookups at
    admission, and ``positions_exhausted`` counts requests rejected because
    prompt+budget exceeds the pool's position capacity (the admission-time
    check that replaces silently running out of positions mid-decode)."""
    n_slots: int = 0
    steps: int = 0              # lock-step decode iterations
    live_slot_steps: int = 0    # sum over steps of live slots that step
    admitted: int = 0           # requests prefilled into a slot
    finished: int = 0           # requests retired (EOS or budget)
    recycles: int = 0           # admissions into a previously-used slot
    canceled: int = 0           # live slots freed without a result
    interrupted: bool = False   # run ended by graceful drain
    weight_bytes_fp: int = 0    # resident fp param bytes (engine load)
    weight_bytes_int8: int = 0  # resident int8 (prequantized) param bytes
    weight_bytes_int4: int = 0  # resident int4-packed param bytes (W4A8)
    pool_bytes: int = 0         # KV pool bytes (pages or dense rows)
    pages_total: int = 0        # page count incl. the reserved scratch page
    pages_free: int = 0         # allocator free-list size
    pages_shared: int = 0       # pages with refcount > 1 (prefix sharing)
    cushion_page_refs: int = 0  # shared cushion block: pool pin + live slots
    prefix_hits: int = 0        # admissions that mapped cached stem pages
    prefix_misses: int = 0      # eligible admissions with no cached stem
    positions_exhausted: int = 0  # requests rejected: prompt+budget > pool
    prefill_chunks: int = 0     # chunked-admission prefill chunks run
    deadline_prefill: int = 0   # streams aborted between chunks (deadline)
    page_table_syncs: int = 0   # host->device page-table mirrors (paged)

    def reset(self) -> None:
        """Zero every per-run counter, keeping ``n_slots``, the resident
        weight bytes and the pool layout facts (``pool_bytes`` /
        ``pages_total``) — load-time configuration. The scheduler calls
        this at the top of each ``run()`` so a stats object shared across
        traces in one process (serve_bench's warm-up pass, repeated bench
        runs) never leaks occupancy counters from the previous run; it
        re-publishes the live allocator gauges right after."""
        self.steps = self.live_slot_steps = 0
        self.admitted = self.finished = self.recycles = self.canceled = 0
        self.interrupted = False
        self.pages_free = self.pages_shared = self.cushion_page_refs = 0
        self.prefix_hits = self.prefix_misses = 0
        self.positions_exhausted = 0
        self.prefill_chunks = self.deadline_prefill = 0
        self.page_table_syncs = 0

    def occupancy(self) -> float:
        return self.live_slot_steps / max(1, self.steps * self.n_slots)

    def as_dict(self) -> dict:
        return {**dataclasses.asdict(self), "occupancy": self.occupancy()}


@dataclasses.dataclass
class RouterStats:
    """Replica-router counters (serving/router.py).

    ``retries`` counts re-enqueues of a request after a failed attempt
    (admission error, replica crash); ``failovers`` counts requests moved
    off a dying replica specifically. ``rejections`` buckets explicit
    backpressure/deadline rejections by reason string. ``queue_depth_peak``
    is the high-water mark of the bounded admission queue — the
    backpressure signal. ``per_replica`` snapshots each replica's
    ``ServeStats`` (and health state) at collection time."""
    n_replicas: int = 0
    submitted: int = 0          # requests accepted into the admission queue
    completed: int = 0          # requests finished with a result
    retries: int = 0            # re-enqueues after a failed attempt
    failovers: int = 0          # live requests moved off a dying replica
    replica_deaths: int = 0     # replicas transitioned to DEAD
    queue_depth_peak: int = 0   # admission-queue high-water mark
    drained: bool = False       # run ended via graceful drain
    rejections: Dict[str, int] = dataclasses.field(default_factory=dict)
    per_replica: List[dict] = dataclasses.field(default_factory=list)

    def reject(self, reason: str) -> None:
        self.rejections[reason] = self.rejections.get(reason, 0) + 1

    @property
    def rejected(self) -> int:
        return sum(self.rejections.values())

    def reset(self) -> None:
        self.submitted = self.completed = 0
        self.retries = self.failovers = self.replica_deaths = 0
        self.queue_depth_peak = 0
        self.drained = False
        self.rejections = {}
        self.per_replica = []

    def as_dict(self) -> dict:
        return {**dataclasses.asdict(self), "rejected": self.rejected}
