"""Deterministic, resumable, shardable synthetic data pipeline.

Stands in for C4/WikiText in the offline container: a Zipf-marginal bigram
language ("synthetic C4") so that small models actually learn structure and
perplexity deltas are meaningful. Every batch is a pure function of
(seed, step, host) — resuming from a checkpointed step reproduces the exact
stream (fault-tolerance requirement), and each data-parallel host draws a
disjoint slice.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class SyntheticCorpus:
    """Bigram LM with Zipfian successor weights."""
    vocab_size: int
    seed: int = 0
    branching: int = 24

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        V, K = self.vocab_size, min(self.branching, self.vocab_size)
        self.successors = np.stack(
            [rng.choice(V, K, replace=False) for _ in range(V)])
        w = 1.0 / np.arange(1, K + 1) ** 1.2
        self.weights = w / w.sum()

    def sample(self, rng: np.random.RandomState, length: int) -> np.ndarray:
        out = np.empty(length, np.int32)
        tok = rng.randint(self.vocab_size)
        for i in range(length):
            out[i] = tok
            tok = self.successors[tok][
                rng.choice(len(self.weights), p=self.weights)]
        return out


@dataclasses.dataclass
class Pipeline:
    corpus: SyntheticCorpus
    batch: int                      # per-host batch
    seq_len: int
    seed: int = 0
    host: int = 0
    n_hosts: int = 1

    def get_batch(self, step: int) -> Dict[str, np.ndarray]:
        """Batch for `step`, deterministic and host-disjoint."""
        toks = np.empty((self.batch, self.seq_len + 1), np.int32)
        for b in range(self.batch):
            rng = np.random.RandomState(
                ((self.seed * 1_000_003 + step) * 65_537
                 + self.host * self.batch + b) % (2 ** 32))
            toks[b] = self.corpus.sample(rng, self.seq_len + 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.get_batch(step)
            step += 1

    def iter_from(self, step: int) -> Iterator[Dict[str, np.ndarray]]:
        """Resume mid-stream (checkpoint restart)."""
        while True:
            yield self.get_batch(step)
            step += 1


def calibration_batches(corpus: SyntheticCorpus, n: int, seq_len: int,
                        seed: int = 777):
    """Held-out calibration samples (the paper's C4 draw)."""
    out = []
    for i in range(n):
        rng = np.random.RandomState(seed + i)
        t = corpus.sample(rng, seq_len + 1)
        out.append({"tokens": t[None, :-1], "labels": t[None, 1:]})
    return out
