"""repro.data"""
