import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell and record memory/cost/collective analysis for §Dry-run and
§Roofline. No real allocation happens — inputs are ShapeDtypeStructs.

Usage:
  python -m repro.launch.dryrun --arch deepseek-67b --shape train_4k \
      [--multipod] [--quant pt_static] [--cushion 16] [--out results.jsonl]
  python -m repro.launch.dryrun --all [--multipod]
"""
import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import (ARCH_IDS, SHAPES, QuantConfig, RunConfig,  # noqa: E402
                           cell_is_applicable, get_config)
from repro.distributed import sharding as SH                # noqa: E402
from repro.distributed.collectives import collective_bytes_of_hlo  # noqa: E402
from repro.launch.mesh import make_production_mesh          # noqa: E402
from repro.models.registry import build                     # noqa: E402
from repro.optim.adamw import AdamW, cosine_lr              # noqa: E402

# TPU v5e hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12
PEAK_FLOPS_INT8 = 394e12
HBM_BW = 819e9
ICI_BW_PER_LINK = 50e9


def cache_shardings(api, cache_abstract, mesh):
    # shared role resolution (divisibility-dropping) with the serving path
    return SH.cache_shardings(api.cache_roles(), cache_abstract, mesh)


def batch_shardings(mesh, specs):
    def one(s):
        bax = SH._resolve_role("B", mesh)
        n = int(np.prod([mesh.shape[a] for a in
                         (bax if isinstance(bax, tuple) else (bax,))]))
        first = bax if s.shape and s.shape[0] % n == 0 else None
        return NamedSharding(mesh, P(first, *([None] * (len(s.shape) - 1))))
    return jax.tree_util.tree_map(one, specs)


def abstract_params(api):
    return jax.eval_shape(lambda: api.init_params(jax.random.PRNGKey(0)))


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               quant: str = "none", cushion_m: int = 0,
               microbatch_policy: str = "auto",
               param_shard: str = "fsdp", prequant: bool = False):
    cfg = get_config(arch)
    api = build(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    shp = SHAPES[shape_name]
    kind = shp["kind"]
    B, S = shp["global_batch"], shp["seq_len"]
    qcfg = QuantConfig(mode=quant, true_int8=(quant == "pt_static"))
    cushion = None
    if cushion_m:
        cushion = api.cushion_zeros(cushion_m, dtype=jnp.float32)
    scales = (api.mod.placeholder_all_scales(cfg)
              if quant != "none" else None)

    p_abs = abstract_params(api)
    if prequant and kind != "train":
        # int8-resident serving weights (attention + dense-MLP matrices)
        from repro.core.quantization import prequantize_tree
        p_abs = jax.eval_shape(
            lambda p: prequantize_tree(p, qcfg), p_abs)
    rules = SH.serve_rules() if (param_shard == "tp" and kind != "train") \
        else SH.DEFAULT_RULES
    p_sh = SH.params_shardings(p_abs, mesh, rules=rules)
    n_b = int(np.prod([mesh.shape[a] for a in
                       (("pod", "data") if multi_pod else ("data",))]))

    with SH.use_mesh(mesh):
        if kind == "train":
            run = RunConfig(model=cfg, quant=qcfg, seq_len=S, global_batch=B)
            opt = AdamW(lr=cosine_lr(3e-4, 100, 1000))
            o_abs = jax.eval_shape(opt.init, p_abs)
            o_sh = jax.tree_util.tree_map(
                lambda _: None, o_abs)  # placeholder, built below
            from repro.optim.adamw import AdamWState
            o_sh = AdamWState(step=NamedSharding(mesh, P()),
                              mu=SH.params_shardings(o_abs.mu, mesh),
                              nu=SH.params_shardings(o_abs.nu, mesh))
            if microbatch_policy == "auto":
                microbatches = max(1, B // n_b)   # per-device microbatch 1
            else:
                microbatches = int(microbatch_policy)
            from repro.train.trainer import make_train_step
            step_fn = make_train_step(api, run, opt,
                                      microbatches=microbatches,
                                      cushion=cushion)
            b_specs = api.input_specs(B, S)
            b_sh = batch_shardings(mesh, b_specs)
            fn = jax.jit(step_fn, in_shardings=(p_sh, o_sh, b_sh),
                         donate_argnums=(0, 1))
            lowered = fn.lower(p_abs, o_abs, b_specs)
        elif kind == "prefill":
            c_abs = jax.eval_shape(lambda: api.init_cache(B, S + cushion_m))
            c_sh = cache_shardings(api, c_abs, mesh)
            b_specs = api.input_specs(B, S)
            b_specs.pop("labels", None)
            b_sh = batch_shardings(mesh, b_specs)

            def prefill_fn(params, batch, cache):
                return api.prefill(params, batch, cache, qcfg,
                                   cushion=cushion, scales=scales)
            fn = jax.jit(prefill_fn, in_shardings=(p_sh, b_sh, c_sh),
                         donate_argnums=(2,))
            lowered = fn.lower(p_abs, b_specs, c_abs)
        else:  # decode
            c_abs = jax.eval_shape(lambda: api.init_cache(B, S + cushion_m))
            c_sh = cache_shardings(api, c_abs, mesh)
            tok = jax.ShapeDtypeStruct((B,), jnp.int32)
            tok_sh = batch_shardings(mesh, {"t": tok})["t"]
            pos = jax.ShapeDtypeStruct((), jnp.int32)

            def decode_fn(params, token, pos, cache):
                return api.decode_step(params, token, pos, cache, qcfg,
                                       scales=scales)
            fn = jax.jit(decode_fn,
                         in_shardings=(p_sh, tok_sh,
                                       NamedSharding(mesh, P()), c_sh),
                         donate_argnums=(3,))
            lowered = fn.lower(p_abs, tok, pos, c_abs)

        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

    record = analyze(compiled, arch, shape_name, multi_pod, kind, quant,
                     cushion_m, cfg, B, S, mesh, param_shard, prequant)
    record["compile_s"] = round(compile_s, 1)
    record["param_shard"] = param_shard
    record["prequant"] = prequant
    return record


def analyze(compiled, arch, shape_name, multi_pod, kind, quant, cushion_m,
            cfg, B, S, mesh, param_shard="fsdp", prequant=False):
    chips = mesh.size
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        }
    except Exception as e:  # noqa: BLE001
        mem_d = {"error": str(e)}
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        xla_flops = float(cost.get("flops", 0.0))
        xla_bytes = float(cost.get("bytes accessed", 0.0))
    except Exception as e:  # noqa: BLE001
        xla_flops, xla_bytes = float("nan"), float("nan")
    # trip-count-aware cost model (XLA's counts while bodies once)
    from repro.launch.hlo_cost import analyze_hlo
    try:
        hlo = compiled.as_text()
        hlo_len = len(hlo)
        # archive for offline re-analysis (cost-model iteration w/o recompile)
        import gzip
        os.makedirs("results/hlo", exist_ok=True)
        tag = f"{arch}_{shape_name}_{'2x16x16' if multi_pod else '16x16'}" \
              f"_{quant}_m{cushion_m}_{param_shard}{'_pq' if prequant else ''}"
        with gzip.open(f"results/hlo/{tag}.hlo.gz", "wt") as f:
            f.write(hlo)
        hc = analyze_hlo(hlo)
        flops = hc["flops"]
        bytes_acc = hc["bytes"]
        coll = {"total": hc["collective_bytes"],
                "counts": hc["collective_counts"]}
        del hlo
    except Exception as e:  # noqa: BLE001
        flops, bytes_acc = xla_flops, xla_bytes
        coll = {"total": float("nan"), "error": str(e)}
        hlo_len = 0

    # Roofline terms. cost_analysis of an SPMD-partitioned module reports
    # the per-device program, so terms are per-chip latencies directly.
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = bytes_acc / HBM_BW
    t_coll = coll.get("total", 0) / ICI_BW_PER_LINK
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dom = max(terms, key=lambda k: (terms[k] if terms[k] == terms[k] else -1))

    # MODEL_FLOPS (6ND / 6 N_active D) per device per step
    n_active = cfg.active_param_count()
    tokens = B * S if kind == "train" else (B * S if kind == "prefill" else B)
    mult = 6 if kind == "train" else 2
    model_flops_total = mult * n_active * tokens
    model_flops_per_chip = model_flops_total / chips

    return {
        "arch": arch, "shape": shape_name, "kind": kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "quant": quant, "cushion_m": cushion_m,
        "chips": chips, "global_batch": B, "seq_len": S,
        "flops_per_chip": flops, "bytes_per_chip": bytes_acc,
        "xla_flops_per_chip": xla_flops, "xla_bytes_per_chip": xla_bytes,
        "collective_bytes_per_chip": coll.get("total"),
        "collective_counts": coll.get("counts"),
        "memory": mem_d,
        "terms": terms, "dominant": dom,
        "model_flops_per_chip": model_flops_per_chip,
        "useful_flops_frac": (model_flops_per_chip / flops
                              if flops and flops == flops else None),
        "hlo_chars": hlo_len,
        "params": cfg.param_count(), "active_params": n_active,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--quant", default="none")
    ap.add_argument("--cushion", type=int, default=0)
    ap.add_argument("--microbatches", default="auto")
    ap.add_argument("--param-shard", default="fsdp", choices=["fsdp", "tp"])
    ap.add_argument("--prequant", action="store_true")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"], r["quant"],
                              r.get("cushion_m", 0),
                              r.get("param_shard", "fsdp"),
                              r.get("prequant", False)))
                except Exception:  # noqa: BLE001
                    pass

    cells = []
    meshes = [False, True] if args.both_meshes else [args.multipod]
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                if cell_is_applicable(arch, shape):
                    for mp in meshes:
                        cells.append((arch, shape, mp))
    else:
        assert args.arch and args.shape
        for mp in meshes:
            cells.append((args.arch, args.shape, mp))

    for arch, shape, mp in cells:
        key = (arch, shape, "2x16x16" if mp else "16x16", args.quant,
               args.cushion, args.param_shard, args.prequant)
        if key in done:
            print(f"[skip] {key}")
            continue
        print(f"[dryrun] {key} ...", flush=True)
        t0 = time.time()
        try:
            rec = lower_cell(arch, shape, mp, args.quant, args.cushion,
                             args.microbatches, args.param_shard,
                             args.prequant)
            rec["ok"] = True
        except Exception as e:  # noqa: BLE001
            rec = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if mp else "16x16", "quant": args.quant,
                   "cushion_m": args.cushion, "ok": False,
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
        rec["wall_s"] = round(time.time() - t0, 1)
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")
        status = "OK" if rec.get("ok") else "FAIL"
        print(f"[dryrun] {key} {status} ({rec['wall_s']}s)", flush=True)


if __name__ == "__main__":
    main()
