"""CushionCache tuning launcher: discover → tune → save a versioned
cushion artifact the serving stack can consume.

    python -m repro.launch.tune --arch paper_tiny --steps 60 \
        --out-dir artifacts/cushion --with-scales

The paper's two-stage pipeline, end-to-end:

  1. greedy token search (`core.cushioncache.greedy_search`, compile-once
     fast path) over calibration samples;
  2. extract the prefix KV/state artifact in the model dtype
     (`ModelAPI.extract_cushion`);
  3. gradient prefix tuning of the cushion KV block
     (`core.cushioncache.prefix_tune`: CE + λ·activation-range
     regularizer, compile-once donated step, periodic metric host syncs).
     ``--dp N`` shards tuning batches over a data mesh axis (CPU hosts get
     forced XLA devices automatically, like serve's --tp);
  4. ``--with-scales``: calibrate pt_static site scales under the *tuned*
     cushion (`core.calibration.calibrate_tagged`) and store them with
     their cushion fingerprint;
  5. save a versioned artifact via `checkpoint.store.CheckpointManager`:
     tree ``{"cushion": ..., "scales": ...}`` with the cushion content
     fingerprint and tuning metadata in the manifest ``extra``.

``launch/serve.py --cushion <dir>`` loads the latest version, re-verifies
the fingerprint against the restored bytes, and serves the tuned cushion
through Engine / ContinuousEngine / the replica router;
`serving.engine.plan_quantization` hard-fails if the stored scales'
fingerprint does not match the cushion actually being served.

Before/after quality numbers (last-block max-activation top-1, held-out
perplexity) print at the end and land in ``--report-json``.
"""
from __future__ import annotations

import argparse
import json
import sys


def _sniff_int_arg(name: str) -> int:
    try:
        if name in sys.argv:
            return int(sys.argv[sys.argv.index(name) + 1])
        return next(int(a.split("=", 1)[1]) for a in sys.argv
                    if a.startswith(name + "="))
    except (IndexError, ValueError, StopIteration):
        return 1


def _force_host_devices_for_dp() -> None:
    """--dp N on CPU needs N XLA host devices; the flag only takes effect
    before jax initializes — sniff argv at import time (same pattern as
    launch/serve.py's --tp)."""
    from repro.flags import force_host_device_count
    n = _sniff_int_arg("--dp")
    if n > 1:
        force_host_device_count(n)


_force_host_devices_for_dp()

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import CheckpointManager
from repro.configs import CushionConfig, Family, QuantConfig, get_config, \
    reduced
from repro.core import cushioncache as CC
from repro.core import outliers as OUT
from repro.data.pipeline import Pipeline, SyntheticCorpus
from repro.models.registry import build
from repro.train.trainer import eval_ppl


def _make_batch_fns(api, cfg, args):
    """(sample_fn for search, tune batch generator, held-out eval batches).
    Token-only families draw from the synthetic pipeline (deterministic,
    disjoint step ranges for search/tune/eval); families with extra inputs
    (vlm patches, encdec frames) use `ModelAPI.make_batch`, which generates
    the full batch dict."""
    extras = cfg.family in (Family.VLM, Family.ENCDEC)
    if extras:
        sample_fn = lambda i: api.make_batch(
            jax.random.PRNGKey(args.seed * 7919 + i), 1, args.sample_len)

        def tune_batches():
            i = 0
            while True:
                yield api.make_batch(
                    jax.random.PRNGKey(args.seed * 104729 + 3000 + i),
                    args.batch, args.seq_len)
                i += 1

        eval_batches = [api.make_batch(
            jax.random.PRNGKey(args.seed * 7 + 7000 + i), args.batch,
            args.seq_len) for i in range(args.eval_batches)]
        return sample_fn, tune_batches(), eval_batches

    corpus = SyntheticCorpus(cfg.vocab_size, seed=args.seed)
    sample_pipe = Pipeline(corpus, batch=1, seq_len=args.sample_len,
                           seed=args.seed + 1)
    tune_pipe = Pipeline(corpus, batch=args.batch, seq_len=args.seq_len,
                         seed=args.seed + 2)
    as_dev = lambda b: {k: jnp.asarray(v) for k, v in b.items()}
    sample_fn = lambda i: as_dev(sample_pipe.get_batch(i))

    def tune_batches():
        i = 0
        while True:
            yield as_dev(tune_pipe.get_batch(3000 + i))
            i += 1

    eval_batches = [as_dev(tune_pipe.get_batch(7000 + i))
                    for i in range(args.eval_batches)]
    return sample_fn, tune_batches(), eval_batches


def _quality(api, params, cushion, eval_batches):
    """(max-activation top-1 of the last block input, held-out ppl)."""
    qnone = QuantConfig(mode="none")
    top1 = OUT.last_block_input_stats(api, params, eval_batches[0], qnone,
                                      cushion=cushion)["top1"]
    ppl = eval_ppl(api, params, eval_batches, qnone, cushion=cushion)
    return top1, ppl


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper_tiny")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (matches serve --smoke so a smoke "
                         "artifact serves against smoke params)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out-dir", required=True,
                    help="artifact store (checkpoint.store versioned dir)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore trained params from latest checkpoint "
                         "(same layout as launch/serve.py)")
    # search stage
    ap.add_argument("--max-prefix-len", type=int, default=8)
    ap.add_argument("--candidates", type=int, default=64)
    ap.add_argument("--tau", type=float, default=1.0)
    ap.add_argument("--sample-len", type=int, default=64,
                    help="calibration sample length for the greedy search")
    # tune stage
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--lam", type=float, default=0.05,
                    help="λ on the activation-range regularizer (eq. 11)")
    ap.add_argument("--log-every", type=int, default=10,
                    help="tuning metric host-sync cadence (steps per "
                         "blocking transfer)")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=48,
                    help="tuning/eval batch sequence length")
    ap.add_argument("--dp", type=int, default=1,
                    help="shard tuning batches over a data mesh axis of "
                         "this width (cushion/optimizer state replicated)")
    ap.add_argument("--quant", default="pt_dynamic",
                    help="quantized-forward mode the tuning loss runs "
                         "under (straight-through fake quant)")
    ap.add_argument("--eval-batches", type=int, default=4)
    # artifact contents
    ap.add_argument("--with-scales", action="store_true",
                    help="calibrate pt_static site scales under the tuned "
                         "cushion and store them (fingerprint-tagged) in "
                         "the artifact")
    ap.add_argument("--calib-batches", type=int, default=2)
    ap.add_argument("--report-json", default=None,
                    help="write the search/tune log + quality numbers here")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg, dtype="float32")
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(args.seed))
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir)
        step = ckpt.latest_step()
        if step is not None:
            from repro.optim.adamw import AdamW, constant_lr
            opt_state = AdamW(lr=constant_lr(1e-3)).init(params)
            like = {"params": params, "opt": opt_state._asdict()}
            params = ckpt.restore(step, like=like)["params"]
            print(f"[tune] restored step {step}")

    qcfg = QuantConfig(mode=args.quant)
    ccfg = CushionConfig(max_prefix_len=args.max_prefix_len, tau=args.tau,
                         sample_len=args.sample_len,
                         n_candidates=args.candidates, seed_tokens=(1,),
                         lam=args.lam, tune_steps=args.steps,
                         tune_lr=args.lr, log_every=args.log_every)
    mesh = None
    if args.dp > 1:
        from repro.launch.mesh import make_tp_mesh
        mesh = make_tp_mesh(1, data=args.dp)
        if args.batch % args.dp:
            ap.error(f"--batch {args.batch} must divide over --dp {args.dp}")
        print(f"[tune] data-parallel tuning over "
              f"{[str(d) for d in mesh.devices.flat]}")

    sample_fn, tune_iter, eval_batches = _make_batch_fns(api, cfg, args)

    # stage 1: greedy search + artifact extraction (model dtype)
    greedy, sr, _ = CC.discover(api, params, sample_fn, iter(()), qcfg,
                                ccfg, jax.random.PRNGKey(args.seed + 2),
                                skip_tune=True)
    print(f"[tune] greedy prefix {sr.prefix_ids.tolist()} "
          f"({sr.wall_time_s:.1f}s, {len(sr.history)} iterations)")
    g_top1, g_ppl = _quality(api, params, greedy, eval_batches)

    # stage 2: gradient prefix tuning of the cushion KV block
    tr = CC.prefix_tune(api, params, greedy, tune_iter, qcfg, ccfg,
                        mesh=mesh)
    tuned = tr.cushion
    t_top1, t_ppl = _quality(api, params, tuned, eval_batches)
    print(f"[tune] {args.steps} steps in {tr.wall_time_s:.1f}s; "
          f"max-activation top1 {g_top1:.1f} -> {t_top1:.1f}, "
          f"held-out ppl {g_ppl:.2f} -> {t_ppl:.2f}")

    fp = CC.cushion_fingerprint(tuned)
    tree = {"cushion": tuned}
    extra = {"kind": "cushion", "arch": cfg.name,
             "family": str(cfg.family), "dtype": cfg.dtype,
             "fingerprint": fp,
             "prefix_ids": [int(t) for t in sr.prefix_ids],
             "quant_mode": args.quant, "tune_steps": args.steps,
             "lam": args.lam, "lr": args.lr, "smoke": bool(args.smoke),
             "maxact_top1": {"greedy": g_top1, "tuned": t_top1},
             "ppl": {"greedy": g_ppl, "tuned": t_ppl}}
    if args.with_scales:
        from repro.core.calibration import calibrate_tagged, scales_to_plain
        qstat = QuantConfig(mode="pt_static", true_int8=True)
        calib = [b for _, b in zip(range(args.calib_batches), tune_iter)]
        tagged, _ = calibrate_tagged(api, params, calib, qstat,
                                     cushion=tuned)
        tree["scales"] = scales_to_plain(tagged.scales)
        extra["scales_cushion_fp"] = tagged.cushion_fp
        print(f"[tune] pt_static scales calibrated under the tuned cushion "
              f"({len(calib)} batches)")

    store = CheckpointManager(args.out_dir)
    version = (store.latest_step() or 0) + 1
    path = store.save(version, tree, extra=extra)
    print(f"[tune] artifact v{version} -> {path} "
          f"(fingerprint {fp[:12]}, scales="
          f"{'yes' if 'scales' in tree else 'no'})")

    if args.report_json:
        with open(args.report_json, "w") as f:
            json.dump({"search": sr.history, "tune_log": tr.log,
                       "artifact": path, **extra}, f, indent=1)
        print(f"[tune] report -> {args.report_json}")
    return path


if __name__ == "__main__":
    main()
