"""Trip-count-aware cost model over optimized HLO text.

XLA's `compiled.cost_analysis()` counts while-loop bodies ONCE, which makes
scan-over-layers dry-runs undercount FLOPs/bytes/collectives by ~L x M
(layers x microbatches). This module parses the optimized HLO module,
builds the computation call graph, extracts static trip counts from loop
conditions, and accumulates:

  * flops            — dot / convolution FLOPs from shapes
  * bytes            — HBM traffic proxy: operand+output bytes of top-level
                       instructions per computation (fusion interiors are
                       free, matching XLA fusion semantics)
  * collective_bytes — result bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute

each multiplied by the enclosing loops' trip counts.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
               "s4": 1, "u4": 1}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([a-z][\w\-]*)\((.*)$")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def _shapes(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _nbytes(text: str) -> int:
    tot = 0
    for dt, dims in _shapes(text):
        n = 1
        for d in dims:
            n *= d
        tot += n * DTYPE_BYTES[dt]
    return tot


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    out_type: str
    rest: str          # args + attrs (single line)


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]


def parse_module(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_START_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(name=m.group(1), instrs=[])
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            cur.instrs.append(Instr(name=m.group(1), opcode=m.group(3),
                                    out_type=m.group(2), rest=m.group(4)))
    if cur is not None:
        comps[cur.name] = cur
    return comps


def _called(rest: str) -> List[str]:
    out = []
    for attr in ("calls=", "body=", "to_apply="):
        m = re.search(re.escape(attr) + r"%?([\w\.\-]+)", rest)
        if m:
            out.append(m.group(1))
    m = re.search(r"branch_computations=\{([^}]*)\}", rest)
    if m:
        out += [c.strip().lstrip("%") for c in m.group(1).split(",")]
    return out


def _root_opcode(comps, rest: str) -> str:
    """Opcode of the ROOT instruction of the computation a fusion calls."""
    m = re.search(r"calls=%?([\w\.\-]+)", rest)
    if not m:
        return ""
    comp = comps.get(m.group(1))
    if comp is None or not comp.instrs:
        return ""
    return comp.instrs[-1].opcode


def _cond_of(rest: str) -> Optional[str]:
    m = re.search(r"condition=%?([\w\.\-]+)", rest)
    return m.group(1) if m else None


def trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    """Static trip count of a counted loop: the integer constant in the
    condition computation (scan lowers to `i < N`)."""
    comp = comps.get(cond_name)
    if comp is None:
        return 1
    best = 1
    for ins in comp.instrs:
        if ins.opcode == "constant":
            m = re.match(r"(\d+)\)", ins.rest)
            if m:
                best = max(best, int(m.group(1)))
        for m in re.finditer(r"constant\((\d+)\)", ins.rest):
            best = max(best, int(m.group(1)))
    return best


def _first_shape(text: str):
    s = _shapes(text)
    return s[0][1] if s else None


def _dot_flops(ins: Instr, shape_of: Dict[str, list]) -> float:
    out_shapes = _shapes(ins.out_type)
    if not out_shapes:
        return 0.0
    out_n = 1
    for d in out_shapes[0][1]:
        out_n *= d
    # lhs shape: from inline type if present, else resolve operand name
    args = ins.rest.split(")")[0]
    opnds = _shapes(args)
    if opnds:
        lhs = opnds[0][1]
    else:
        names = _OPERAND_RE.findall(args)
        lhs = shape_of.get(names[0]) if names else None
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rest)
    contract = 1
    if m and lhs:
        for idx in m.group(1).split(","):
            if idx and int(idx) < len(lhs):
                contract *= lhs[int(idx)]
    # batch dims are part of out_n already
    return 2.0 * out_n * contract


def _conv_flops(ins: Instr, shape_of: Dict[str, list]) -> float:
    out_shapes = _shapes(ins.out_type)
    if not out_shapes:
        return 0.0
    out_n = 1
    for d in out_shapes[0][1]:
        out_n *= d
    args = ins.rest.split(")")[0]
    opnds = _shapes(args)
    if len(opnds) >= 2:
        kernel = opnds[1][1]
    else:
        names = _OPERAND_RE.findall(args)
        kernel = shape_of.get(names[1]) if len(names) > 1 else None
    if not kernel:
        return 0.0
    kn = 1
    for d in kernel:
        kn *= d
    out_ch = kernel[-1] if kernel else 1
    return 2.0 * out_n * max(1, kn // max(1, out_ch))


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_counts: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {c: 0 for c in COLLECTIVES})

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.collective_bytes += o.collective_bytes
        for c in COLLECTIVES:
            self.collective_counts[c] += o.collective_counts[c]
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k,
                    self.collective_bytes * k,
                    {c: int(self.collective_counts[c] * k)
                     for c in COLLECTIVES})


_FREE_OPS = ("parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "while", "after-all", "partition-id", "replica-id")



def _param_slice_bytes(comp: "Computation") -> Dict[int, int]:
    """For a fused computation: parameters consumed (possibly through
    bitcast/convert/copy) by dynamic-slice/gather are charged at SLICE
    size at the call site (the fusion reads one layer of a scan-stacked
    buffer, not the whole stack)."""
    if comp is None:
        return {}
    param_idx = {}
    alias = {}
    for ins in comp.instrs:
        if ins.opcode == "parameter":
            m = re.match(r"(\d+)\)", ins.rest)
            if m:
                param_idx[ins.name] = int(m.group(1))
        elif ins.opcode in ("bitcast", "convert", "copy", "reshape",
                            "transpose"):
            ops = _OPERAND_RE.findall(ins.rest.split(")")[0])
            if ops:
                alias[ins.name] = ops[0]
    def resolve(name, depth=0):
        if name in param_idx or depth > 4:
            return name
        if name in alias:
            return resolve(alias[name], depth + 1)
        return name
    out: Dict[int, int] = {}
    for ins in comp.instrs:
        if ins.opcode in ("dynamic-slice", "gather"):
            ops = _OPERAND_RE.findall(ins.rest.split(")")[0])
            if not ops:
                continue
            src = resolve(ops[0])
            if src in param_idx:
                nb = _nbytes(ins.out_type)
                i = param_idx[src]
                out[i] = min(out.get(i, nb), nb)
    return out


def comp_cost(comps: Dict[str, Computation], name: str,
              memo: Dict[str, Cost], fused: bool = False) -> Cost:
    if name in memo:
        return memo[name]
    memo[name] = Cost()        # break cycles defensively
    comp = comps.get(name)
    if comp is None:
        return memo[name]
    # local name -> output bytes / shape (to resolve operand reads)
    out_bytes = {ins.name: _nbytes(ins.out_type) for ins in comp.instrs}
    shape_of = {ins.name: _first_shape(ins.out_type) for ins in comp.instrs}
    total = Cost()
    for ins in comp.instrs:
        op = ins.opcode
        if op == "dot":
            total.flops += _dot_flops(ins, shape_of)
        elif op == "convolution":
            total.flops += _conv_flops(ins, shape_of)
        base = op.replace("-start", "").replace("-done", "")
        if base in COLLECTIVES and not op.endswith("-done"):
            total.collective_bytes += _nbytes(ins.out_type)
            total.collective_counts[base] += 1
        if op == "while":
            m = re.search(r"body=%?([\w\.\-]+)", ins.rest)
            body = m.group(1) if m else None
            cond = _cond_of(ins.rest)
            trips = trip_count(comps, cond) if cond else 1
            if body:
                total += comp_cost(comps, body, memo).scaled(trips)
            continue
        for callee in _called(ins.rest):
            sub = comp_cost(comps, callee, memo, fused=True)
            # fusion interiors contribute flops/collectives but not bytes
            total.flops += sub.flops
            total.collective_bytes += sub.collective_bytes
            for c in COLLECTIVES:
                total.collective_counts[c] += sub.collective_counts[c]
        # HBM-traffic proxy: write output + read operands (resolved locally)
        if not fused and op not in _FREE_OPS:
            args = ins.rest.split("), ")[0]
            opnd_bytes = [out_bytes.get(o, 0)
                          for o in _OPERAND_RE.findall(args)]
            if op == "dynamic-slice":
                # reads only the slice it produces
                b = 2 * _nbytes(ins.out_type)
            elif op == "dynamic-update-slice" or (
                    op == "fusion" and _root_opcode(comps, ins.rest)
                    == "dynamic-update-slice"):
                # in-place update: traffic ~ update inputs + slice write,
                # NOT the full aliased buffer (scan ys / KV-cache writes)
                small = sorted(opnd_bytes)[:-1] if opnd_bytes else []
                b = 2 * sum(small)
            else:
                if op == "fusion":
                    m2 = re.search(r"calls=%?([\w\.\-]+)", ins.rest)
                    slice_map = _param_slice_bytes(
                        comps.get(m2.group(1))) if m2 else {}
                    opnd_bytes = [slice_map.get(i, v)
                                  for i, v in enumerate(opnd_bytes)]
                b = _nbytes(ins.out_type) + sum(opnd_bytes)
            total.bytes += b
    memo[name] = total
    return total


def analyze_hlo(hlo: str) -> Dict[str, float]:
    comps = parse_module(hlo)
    entry = None
    m = re.search(r"ENTRY\s+%?([\w\.\-]+)", hlo)
    if m:
        entry = m.group(1)
    if entry is None or entry not in comps:
        # fall back: the computation with most instructions
        entry = max(comps, key=lambda k: len(comps[k].instrs))
    memo: Dict[str, Cost] = {}
    c = comp_cost(comps, entry, memo)
    return {"flops": c.flops, "bytes": c.bytes,
            "collective_bytes": c.collective_bytes,
            "collective_counts": c.collective_counts}
