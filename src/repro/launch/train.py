"""Fault-tolerant training launcher.

    python -m repro.launch.train --arch paper_tiny --steps 300 \
        [--smoke] [--ckpt-dir /tmp/ckpt] [--resume] [--quant pt_static]

On CPU this trains the reduced/paper-scale configs; on a pod the identical
entrypoint compiles against the production mesh (--mesh single|multi).
The Supervisor provides retry/restore, straggler flagging, and deterministic
data replay from the checkpointed step.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import CheckpointManager
from repro.configs import QuantConfig, RunConfig, get_config, reduced
from repro.data.pipeline import Pipeline, SyntheticCorpus
from repro.distributed import sharding as SH
from repro.distributed.fault_tolerance import Supervisor
from repro.models.registry import build
from repro.optim.adamw import AdamW, cosine_lr
from repro.train.trainer import eval_ppl, make_optimizer, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper_tiny")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config of the arch family")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--quant", default="none")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eval-batches", type=int, default=8)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg, dtype="float32")
    elif args.arch == "paper_tiny":
        pass
    api = build(cfg)
    run = RunConfig(model=cfg, quant=QuantConfig(mode=args.quant),
                    seq_len=args.seq, global_batch=args.batch, lr=args.lr,
                    train_steps=args.steps,
                    warmup_steps=max(10, args.steps // 20))

    corpus = SyntheticCorpus(cfg.vocab_size, seed=args.seed)
    pipe = Pipeline(corpus, batch=args.batch, seq_len=args.seq,
                    seed=args.seed)

    rng = jax.random.PRNGKey(args.seed)
    params = api.init_params(rng)
    opt = make_optimizer(run)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(api, run, opt))

    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    state = {"params": params, "opt": opt_state._asdict()}
    step0 = 0
    if args.resume and ckpt.latest_step() is not None:
        step0 = ckpt.latest_step()
        state = ckpt.restore(step0, like=state)
        print(f"[train] resumed from step {step0}")

    from repro.optim.adamw import AdamWState
    sup = Supervisor(ckpt, save_every=args.save_every)
    log = []

    def do_step(state, step):
        batch = {k: jnp.asarray(v) for k, v in pipe.get_batch(step).items()}
        p, o, metrics = step_fn(state["params"],
                                AdamWState(**state["opt"]), batch)
        return {"params": p, "opt": o._asdict()}, metrics

    def on_metrics(step, metrics):
        if step % 20 == 0:
            rec = {"step": step, **{k: float(v) for k, v in metrics.items()}}
            log.append(rec)
            print(f"[train] step={step} loss={rec['loss']:.4f} "
                  f"lr={rec.get('lr', 0):.2e}")

    t0 = time.time()
    state, report = sup.run(state, step0, args.steps - step0, do_step,
                            on_metrics=on_metrics)
    wall = time.time() - t0

    eval_batches = [
        {k: jnp.asarray(v) for k, v in pipe.get_batch(10_000 + i).items()}
        for i in range(args.eval_batches)]
    ppl = eval_ppl(api, state["params"], eval_batches, run.quant)
    print(f"[train] done steps={report.completed_steps} wall={wall:.1f}s "
          f"eval_ppl={ppl:.3f} failures={report.failures} "
          f"stragglers={len(report.stragglers)}")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump({"ppl": ppl, "wall_s": wall, "log": log,
                       "report": dataclasses.asdict(report)}, f)
    return state, ppl


if __name__ == "__main__":
    main()
