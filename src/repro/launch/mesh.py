"""Production meshes. Functions (not module-level constants) so importing
never touches jax device state.

Single pod:  (data=16, model=16)          = 256 chips (TPU v5e-256)
Multi-pod:   (pod=2, data=16, model=16)   = 512 chips (2 pods over DCN)
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}; have {len(devices)}. "
            "The dry-run launcher must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax.")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_mesh(shape, axes) -> Mesh:
    n = int(np.prod(shape))
    return Mesh(np.asarray(jax.devices()[:n]).reshape(shape), axes)


def single_device_mesh() -> Mesh:
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("data",))


def make_tp_mesh(tp: int, data: int = 1) -> Mesh:
    """Serving mesh: ``(data, tp)``. The ``tp`` axis name activates serving
    tensor parallelism in the role resolver (distributed/sharding.py): "M"
    roles — attention heads, d_ff, experts, vocab, the KV-pool heads axis —
    shard over ``tp``; ``data`` is pure batch replication. ``tp=1`` yields a
    trivial mesh (useful for exercising the sharded code path on one
    device)."""
    n = data * tp
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for a (data={data}, tp={tp}) mesh; have "
            f"{len(devices)}. On CPU, set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n} before importing "
            "jax to emulate a multi-device host.")
    return Mesh(np.asarray(devices[:n]).reshape(data, tp), ("data", "tp"))


def make_replica_meshes(n: int, tp: int = 1) -> list:
    """Data-parallel replica meshes for the serving router: partition the
    first ``n * tp`` devices into ``n`` disjoint ``(data=1, tp)`` meshes,
    one per ``ReplicaRouter`` replica. Each replica's ContinuousEngine runs
    its own independent device program on its own group — replica isolation
    is what makes killing one replica survivable, so replicas deliberately
    do NOT share a mesh axis."""
    devices = jax.devices()
    if len(devices) < n * tp:
        raise RuntimeError(
            f"need {n * tp} devices for {n} replicas x tp={tp}; have "
            f"{len(devices)}. On CPU, set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n * tp} before "
            "importing jax to emulate a multi-device host.")
    return [Mesh(np.asarray(devices[i * tp:(i + 1) * tp]).reshape(1, tp),
                 ("data", "tp")) for i in range(n)]
