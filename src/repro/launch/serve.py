"""Serving launcher: batched generation under a quantization mode with an
optional CushionCache artifact.

    python -m repro.launch.serve --arch paper_tiny --quant pt_static \
        --cushion artifacts/cushion.npz --tokens 64

The default (static) mode runs one Engine batch: device-resident decode
(one jitted lax.scan — no per-token host sync); --kv-dtype int8 serves
from a quantized KV cache with the cushion prefix kept intact in fp.

--quant pt_static serves the calibrated true-int8 W8A8 deployment path:
site scales are calibrated at engine load over --calib-batches synthetic
batches (under the cushion when one is attached), and --prequant makes the
weights int8-resident ({w_int, w_scale, colsum} dicts; decode streams
1 byte/weight through the Pallas w8a8_matmul path on TPU):

    python -m repro.launch.serve --arch paper_tiny --quant pt_static \
        --prequant --bench-json results/BENCH_w8a8.json

--mode continuous replays a Poisson-arrival request trace through the
continuous-batching scheduler (``serving.scheduler.ContinuousEngine``):
requests arrive at --rate req/s, are admitted into a pool of --slots cache
slots as they free up, and decode in lock-step with per-slot positions.
Prints per-request TTFT/TPOT plus aggregate tokens/s, latency percentiles
and slot occupancy. --bench-json PATH appends a trajectory point for perf
regression tracking in either mode.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _force_host_devices_for_tp() -> None:
    """--tp N on CPU needs N XLA host devices, and the flag only takes
    effect before jax initializes — sniff argv at import time (same pattern
    as launch/dryrun.py)."""
    from repro.flags import force_host_device_count
    try:
        if "--tp" in sys.argv:
            tp = int(sys.argv[sys.argv.index("--tp") + 1])
        else:       # argparse also accepts the --tp=N form
            tp = next(int(a.split("=", 1)[1]) for a in sys.argv
                      if a.startswith("--tp="))
    except (IndexError, ValueError, StopIteration):
        return
    force_host_device_count(tp)


_force_host_devices_for_tp()

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import CheckpointManager
from repro.configs import QuantConfig, get_config, reduced
from repro.data.pipeline import Pipeline, SyntheticCorpus
from repro.models.registry import build
from repro.serving.engine import Engine
from repro.serving.scheduler import ContinuousEngine, Request


def poisson_trace(api, rng_seed: int, n_requests: int, rate: float,
                  prompt_lens, budgets) -> list:
    """Poisson-arrival request trace: exponential inter-arrival gaps at
    ``rate`` req/s, prompts cycling through ``prompt_lens`` (total
    positions) and budgets through ``budgets``."""
    rs = np.random.RandomState(rng_seed)
    t = 0.0
    reqs = []
    for i in range(n_requests):
        t += float(rs.exponential(1.0 / rate)) if rate > 0 else 0.0
        reqs.append(Request(
            uid=i,
            batch=api.make_batch(jax.random.PRNGKey(rng_seed + 7 * i + 1), 1,
                                 int(prompt_lens[i % len(prompt_lens)])),
            max_new_tokens=int(budgets[i % len(budgets)]),
            arrival_s=t))
    return reqs


def run_continuous(api, params, qcfg, args, bench_path=None, mesh=None,
                   calib_batches=None):
    reqs = poisson_trace(api, args.seed, args.n_requests, args.rate,
                         prompt_lens=(args.prompt_len, args.prompt_len + 8),
                         budgets=(args.tokens, max(1, args.tokens // 2)))
    eng = ContinuousEngine(api, params, qcfg, n_slots=args.slots,
                           max_seq=args.prompt_len + 8 + args.tokens + 32,
                           mesh=mesh,
                           kv_dtype=None if args.kv_dtype == "fp"
                           else args.kv_dtype,
                           calib_batches=calib_batches,
                           prequant=args.prequant)
    print(f"[serve] resident weights: "
          f"fp={eng.stats.weight_bytes_fp / 2 ** 20:.1f} MiB "
          f"int8={eng.stats.weight_bytes_int8 / 2 ** 20:.1f} MiB")
    if bench_path:
        eng.run(reqs)           # warm/compile pass; measure steady state
    outs = eng.run(reqs)
    total = sum(len(o.tokens) for o in outs)
    span = max(o.finished_s for o in outs) - min(r.arrival_s for r in reqs)
    lat = np.asarray([o.latency_s for o in outs])
    tps = total / max(span, 1e-9)
    occ = eng.stats.occupancy()
    for o in outs:
        print(f"[serve]   req {o.uid}: slot {o.slot} n={len(o.tokens)} "
              f"TTFT={o.ttft_ms:.1f}ms TPOT={o.tpot_ms:.2f}ms "
              f"latency={o.latency_s * 1e3:.0f}ms")
    print(f"[serve] continuous: {len(outs)} reqs, {total} tokens, "
          f"{tps:.1f} tok/s, p50={np.percentile(lat, 50) * 1e3:.0f}ms "
          f"p99={np.percentile(lat, 99) * 1e3:.0f}ms occupancy={occ:.2f}")
    if bench_path:
        point = {"mode": "continuous", "arch": args.arch,
                 "quant": args.quant, "prequant": args.prequant,
                 "kv_dtype": args.kv_dtype, "slots": args.slots,
                 "rate": args.rate, "n_requests": args.n_requests,
                 "tokens_per_s": tps,
                 "p50_latency_s": float(np.percentile(lat, 50)),
                 "p99_latency_s": float(np.percentile(lat, 99)),
                 "occupancy": occ, **eng.stats.as_dict()}
        _append_point(bench_path, point)
    return outs


def _append_point(path: str, point: dict) -> None:
    hist = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                prev = json.load(f)
            hist = prev if isinstance(prev, list) else [prev]
        except (json.JSONDecodeError, OSError) as e:
            print(f"[serve] WARNING: could not read {path} "
                  f"({e}); starting a fresh trajectory")
    hist.append(point)
    with open(path, "w") as f:
        json.dump(hist, f, indent=1)
    print(f"[serve] bench point -> {path}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper_tiny")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quant", default="none")
    ap.add_argument("--mode", default="static",
                    choices=["static", "continuous"],
                    help="static: one Engine batch; continuous: Poisson "
                         "trace through the slot-pool scheduler")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4,
                    help="continuous mode: cache-slot pool size")
    ap.add_argument("--rate", type=float, default=8.0,
                    help="continuous mode: Poisson arrival rate (req/s)")
    ap.add_argument("--n-requests", type=int, default=8,
                    help="continuous mode: trace length")
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore params from latest checkpoint")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel width: shard params (serve rules) "
                         "and the KV pool heads axis over a (data=1, tp=N) "
                         "mesh; works on CPU via forced host devices (set "
                         "automatically at import) and on real accelerator "
                         "meshes alike")
    ap.add_argument("--kv-dtype", default="fp", choices=["fp", "int8"],
                    help="KV-cache storage precision (int8 halves decode "
                         "HBM traffic; cushion prefix stays fp; the "
                         "continuous pool calibrates per-slot scales at "
                         "each admission prefill)")
    ap.add_argument("--prequant", action="store_true",
                    help="serve int8-resident weights: calibrate pt_static "
                         "site scales at load, prequantize the param tree "
                         "(1 byte/weight streamed into the W8A8 matmul "
                         "path); requires --quant pt_static")
    ap.add_argument("--calib-batches", type=int, default=2,
                    help="pt_static: number of calibration batches drawn "
                         "from the synthetic pipeline at engine load")
    ap.add_argument("--bench-json", default=None,
                    help="append a trajectory point to this file")
    args = ap.parse_args(argv)
    if args.prequant and args.quant != "pt_static":
        ap.error("--prequant requires --quant pt_static (int8-resident "
                 "weights serve the per-tensor static deployment path)")

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg, dtype="float32")
    api = build(cfg)
    rng = jax.random.PRNGKey(args.seed)
    params = api.init_params(rng)
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir)
        step = ckpt.latest_step()
        if step is not None:
            from repro.optim.adamw import AdamW, constant_lr
            opt_state = AdamW(lr=constant_lr(1e-3)).init(params)
            like = {"params": params, "opt": opt_state._asdict()}
            params = ckpt.restore(step, like=like)["params"]
            print(f"[serve] restored step {step}")

    # pt_static serves the true-int8 deployment path (the one --prequant
    # makes int8-resident); dynamic modes keep the fake-quant fidelity path
    qcfg = QuantConfig(mode=args.quant,
                       true_int8=args.quant == "pt_static")
    mesh = None
    if args.tp > 1:
        from repro.launch.mesh import make_tp_mesh
        mesh = make_tp_mesh(args.tp)
        print(f"[serve] tp={args.tp} mesh over "
              f"{[str(d) for d in mesh.devices.flat]}")

    corpus = SyntheticCorpus(cfg.vocab_size, seed=args.seed)
    pipe = Pipeline(corpus, batch=args.batch, seq_len=args.prompt_len,
                    seed=args.seed + 1)
    calib = None
    if args.quant == "pt_static":
        calib = [{k: jnp.asarray(v) for k, v in pipe.get_batch(1000 + i).items()}
                 for i in range(args.calib_batches)]
        print(f"[serve] pt_static: calibrating site scales over "
              f"{len(calib)} batches at engine load")

    if args.mode == "continuous":
        return run_continuous(api, params, qcfg, args,
                              bench_path=args.bench_json, mesh=mesh,
                              calib_batches=calib)

    batch = {k: jnp.asarray(v) for k, v in pipe.get_batch(0).items()}

    eng = Engine(api, params, qcfg,
                 max_seq=args.prompt_len + args.tokens + 32,
                 kv_dtype=None if args.kv_dtype == "fp" else args.kv_dtype,
                 mesh=mesh, calib_batches=calib, prequant=args.prequant)
    print(f"[serve] resident weights: "
          f"fp={eng.weight_bytes_fp / 2 ** 20:.1f} MiB "
          f"int8={eng.weight_bytes_int8 / 2 ** 20:.1f} MiB")
    if args.bench_json:
        eng.generate(batch, args.tokens)     # warm/compile: the recorded
        # point must measure steady-state decode, not scan-loop tracing
    res = eng.generate(batch, args.tokens)
    print(f"[serve] B={args.batch} prompt={args.prompt_len} "
          f"gen={args.tokens} kv={args.kv_dtype} tp={args.tp} "
          f"TTFT={res.ttft_ms:.1f}ms TPOT={res.tpot_ms:.2f}ms")
    print("[serve] sample:", res.tokens[0][:16].tolist())
    if args.bench_json:
        _append_point(args.bench_json, {
            "mode": "static", "arch": args.arch, "quant": args.quant,
            "prequant": args.prequant, "kv_dtype": args.kv_dtype,
            "batch": args.batch, "tp": args.tp,
            "prompt_len": args.prompt_len, "tokens": args.tokens,
            "weight_bytes_fp": eng.weight_bytes_fp,
            "weight_bytes_int8": eng.weight_bytes_int8,
            "ttft_ms": res.ttft_ms, "tpot_ms": res.tpot_ms})
    return res


if __name__ == "__main__":
    main()
