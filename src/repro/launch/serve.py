"""Serving launcher: batched generation under a quantization mode with an
optional CushionCache artifact.

    python -m repro.launch.serve --arch paper_tiny --quant pt_static \
        --cushion artifacts/cushion.npz --tokens 64

The decode loop is device-resident (one jitted lax.scan — no per-token host
sync); --kv-dtype int8 serves from a quantized KV cache with the cushion
prefix kept intact in fp. --bench-json PATH appends a TTFT/TPOT trajectory
point for perf regression tracking.
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import CheckpointManager
from repro.configs import QuantConfig, get_config, reduced
from repro.data.pipeline import Pipeline, SyntheticCorpus
from repro.models.registry import build
from repro.serving.engine import Engine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper_tiny")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quant", default="none")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore params from latest checkpoint")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kv-dtype", default="fp", choices=["fp", "int8"],
                    help="KV-cache storage precision (int8 halves decode "
                         "HBM traffic; cushion prefix stays fp)")
    ap.add_argument("--bench-json", default=None,
                    help="append a {ttft,tpot} trajectory point to this file")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg, dtype="float32")
    api = build(cfg)
    rng = jax.random.PRNGKey(args.seed)
    params = api.init_params(rng)
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir)
        step = ckpt.latest_step()
        if step is not None:
            from repro.optim.adamw import AdamW, constant_lr
            opt_state = AdamW(lr=constant_lr(1e-3)).init(params)
            like = {"params": params, "opt": opt_state._asdict()}
            params = ckpt.restore(step, like=like)["params"]
            print(f"[serve] restored step {step}")

    corpus = SyntheticCorpus(cfg.vocab_size, seed=args.seed)
    pipe = Pipeline(corpus, batch=args.batch, seq_len=args.prompt_len,
                    seed=args.seed + 1)
    batch = {k: jnp.asarray(v) for k, v in pipe.get_batch(0).items()}

    qcfg = QuantConfig(mode=args.quant)
    eng = Engine(api, params, qcfg,
                 max_seq=args.prompt_len + args.tokens + 32,
                 kv_dtype=None if args.kv_dtype == "fp" else args.kv_dtype)
    if args.bench_json:
        eng.generate(batch, args.tokens)     # warm/compile: the recorded
        # point must measure steady-state decode, not scan-loop tracing
    res = eng.generate(batch, args.tokens)
    print(f"[serve] B={args.batch} prompt={args.prompt_len} "
          f"gen={args.tokens} kv={args.kv_dtype} "
          f"TTFT={res.ttft_ms:.1f}ms TPOT={res.tpot_ms:.2f}ms")
    print("[serve] sample:", res.tokens[0][:16].tolist())
    if args.bench_json:
        point = {"arch": args.arch, "quant": args.quant,
                 "kv_dtype": args.kv_dtype, "batch": args.batch,
                 "prompt_len": args.prompt_len, "tokens": args.tokens,
                 "ttft_ms": res.ttft_ms, "tpot_ms": res.tpot_ms}
        hist = []
        if os.path.exists(args.bench_json):
            try:
                with open(args.bench_json) as f:
                    prev = json.load(f)
                hist = prev if isinstance(prev, list) else [prev]
            except (json.JSONDecodeError, OSError) as e:
                print(f"[serve] WARNING: could not read {args.bench_json} "
                      f"({e}); starting a fresh trajectory")
        hist.append(point)
        with open(args.bench_json, "w") as f:
            json.dump(hist, f, indent=1)
        print(f"[serve] bench point -> {args.bench_json}")
    return res


if __name__ == "__main__":
    main()
