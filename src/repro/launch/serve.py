"""Serving launcher: batched generation under a quantization mode with an
optional CushionCache artifact.

    python -m repro.launch.serve --arch paper_tiny --quant pt_static \
        --cushion artifacts/cushion --tokens 64

The default (static) mode runs one Engine batch: device-resident decode
(one jitted lax.scan — no per-token host sync); --kv-dtype int8 serves
from a quantized KV cache with the cushion prefix kept intact in fp.

--cushion DIR loads the latest tuned-cushion artifact written by
``launch/tune.py`` (a ``checkpoint.store`` versioned directory). The
content fingerprint is recomputed over the restored bytes and checked
against the manifest — a corrupt or mismatched artifact fails loudly at
load, never as silently drifted activations. If the artifact carries
pt_static scales (tune --with-scales) and --quant pt_static, those scales
serve directly (no load-time calibration) wrapped with their cushion
fingerprint so ``plan_quantization`` can reject a stale pairing; without
stored scales, pt_static calibrates at load *under the loaded cushion*.

--quant pt_static serves the calibrated true-int8 W8A8 deployment path:
site scales are calibrated at engine load over --calib-batches synthetic
batches (under the cushion when one is attached), and --prequant makes the
weights int8-resident ({w_int, w_scale, colsum} dicts; decode streams
1 byte/weight through the Pallas w8a8_matmul path on TPU):

    python -m repro.launch.serve --arch paper_tiny --quant pt_static \
        --prequant --bench-json results/BENCH_w8a8.json

--mode continuous replays a Poisson-arrival request trace through the
continuous-batching scheduler (``serving.scheduler.ContinuousEngine``):
requests arrive at --rate req/s, are admitted into a pool of --slots cache
slots as they free up, and decode in lock-step with per-slot positions.
Prints per-request TTFT/TPOT plus aggregate tokens/s, latency percentiles
and slot occupancy. --bench-json PATH appends a trajectory point for perf
regression tracking in either mode. The trace is fully seedable:
--trace-seed (default --seed) fixes arrivals, prompts and budgets, so two
runs with the same seeds replay the identical workload.

--replicas N serves the trace through the fault-tolerant replica router
(``serving.router.ReplicaRouter``): N data-parallel ContinuousEngine
replicas behind one bounded admission queue with least-loaded dispatch,
health tracking, retry/failover and graceful drain. --chaos injects
deterministic faults (``kind@site:step`` specs, e.g.
``crash@replica1.step:12`` — see distributed/fault_injection.py) to
exercise failover on a live trace:

    python -m repro.launch.serve --arch paper_tiny --smoke \
        --mode continuous --replicas 3 --chaos crash@replica1.step:6

--paged swaps the continuous pool's dense per-slot rows for the paged KV
layout (``serving/paging.py``): a flat page store plus per-slot page
tables, the fp cushion held once (batch-free) instead of per slot, pages
allocated on demand as decode appends and returned at retirement.
--page-size sets the page granularity (must divide max_seq), --pages caps
the physical pool (defaults to worst-case, i.e. no admission ever
backpressures on pages), and --prefix-cache turns on content-addressed
prompt-stem page sharing (fp pools only): repeated prompt stems map the
donor's pages read-only and only prefill the tail. The final stats block
gains the page-pool gauges (pages total/free/shared, cushion page refs,
prefix hit/miss, pool bytes):

    python -m repro.launch.serve --arch paper_tiny --smoke \
        --mode continuous --paged --page-size 32 --prefix-cache

Graceful shutdown (continuous + router modes): SIGTERM and ctrl-C drain
instead of dying mid-step — admission stops, live slots decode to
completion, and the final ServeStats/RouterStats are printed for the
completed prefix of the trace.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _sniff_int_arg(name: str) -> int:
    try:
        if name in sys.argv:
            return int(sys.argv[sys.argv.index(name) + 1])
        return next(int(a.split("=", 1)[1]) for a in sys.argv
                    if a.startswith(name + "="))
    except (IndexError, ValueError, StopIteration):
        return 1


def _force_host_devices_for_tp() -> None:
    """--tp N (x --replicas R) on CPU needs N*R XLA host devices, and the
    flag only takes effect before jax initializes — sniff argv at import
    time (same pattern as launch/dryrun.py)."""
    from repro.flags import force_host_device_count
    n = _sniff_int_arg("--tp") * _sniff_int_arg("--replicas")
    if n > 1:
        force_host_device_count(n)


_force_host_devices_for_tp()

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import CheckpointManager
from repro.configs import QuantConfig, get_config, reduced
from repro.data.pipeline import Pipeline, SyntheticCorpus
from repro.models.registry import build
from repro.serving.engine import Engine
from repro.serving.scheduler import ContinuousEngine, Request


def poisson_trace(api, rng_seed: int, n_requests: int, rate: float,
                  prompt_lens, budgets) -> list:
    """Poisson-arrival request trace: exponential inter-arrival gaps at
    ``rate`` req/s, prompts cycling through ``prompt_lens`` (total
    positions) and budgets through ``budgets``. Fully seedable: everything
    — arrival gaps, prompt contents, budget assignment — derives from
    ``rng_seed``, so the same seed replays the identical workload (the
    chaos parity checks depend on this)."""
    rs = np.random.RandomState(rng_seed)
    t = 0.0
    reqs = []
    for i in range(n_requests):
        t += float(rs.exponential(1.0 / rate)) if rate > 0 else 0.0
        reqs.append(Request(
            uid=i,
            batch=api.make_batch(jax.random.PRNGKey(rng_seed + 7 * i + 1), 1,
                                 int(prompt_lens[i % len(prompt_lens)])),
            max_new_tokens=int(budgets[i % len(budgets)]),
            arrival_s=t))
    return reqs


def load_cushion_artifact(path: str, api):
    """Load the latest cushion artifact from a ``launch/tune.py``
    --out-dir. Returns ``(cushion, tagged_scales | None, extra)``.

    Trust-but-verify: the content fingerprint is recomputed over the
    restored (device) arrays and compared to the manifest's — bit-rot,
    a truncated copy, or a hand-edited artifact dies here with a clear
    message instead of serving subtly wrong prefix KV. The arch name is
    checked too (a smoke artifact only serves a smoke config: `reduced`
    renames the config, so the mismatch is caught, not silently shaped
    in). Stored scales come back as ``calibration.CalibratedScales``
    carrying the fingerprint of the cushion they were calibrated under,
    which `plan_quantization` enforces against the cushion actually
    served."""
    from repro.core.calibration import CalibratedScales, scales_from_plain
    from repro.core.cushioncache import cushion_fingerprint

    store = CheckpointManager(path)
    version = store.latest_step()
    if version is None:
        raise SystemExit(f"[serve] no cushion artifact under {path}")
    tree, manifest = store.restore_tree(version)
    extra = manifest.get("extra", {})
    if extra.get("kind") != "cushion":
        raise SystemExit(f"[serve] {path} v{version} is not a cushion "
                         f"artifact (kind={extra.get('kind')!r}); expected "
                         f"a launch/tune.py --out-dir")
    if extra.get("arch") and extra["arch"] != api.cfg.name:
        raise SystemExit(f"[serve] cushion artifact was tuned for arch "
                         f"{extra['arch']!r} but serving {api.cfg.name!r}")
    cushion = jax.tree_util.tree_map(jnp.asarray, tree["cushion"])
    got = cushion_fingerprint(cushion)
    want = extra.get("fingerprint")
    if want and got != want:
        raise SystemExit(f"[serve] cushion artifact fingerprint mismatch: "
                         f"manifest says {want[:12]} but restored bytes "
                         f"hash to {got[:12]} — artifact corrupt")
    scales = None
    if "scales" in tree:
        scales = CalibratedScales(scales_from_plain(tree["scales"]),
                                  extra.get("scales_cushion_fp", got))
    print(f"[serve] cushion artifact v{version} from {path}: "
          f"prefix_ids={extra.get('prefix_ids')} "
          f"fingerprint={got[:12]} scales="
          f"{'stored' if scales is not None else 'none'}")
    return cushion, scales, extra


def install_sigterm_drain() -> None:
    """Map SIGTERM onto KeyboardInterrupt so orchestrator shutdowns take
    the same graceful-drain path as ctrl-C: stop admitting, decode live
    slots to completion, print final stats. No-op off the main thread
    (pytest workers)."""
    import signal

    def _handler(signum, frame):
        raise KeyboardInterrupt("SIGTERM")

    try:
        signal.signal(signal.SIGTERM, _handler)
    except ValueError:      # not the main thread
        pass


def run_continuous(api, params, qcfg, args, bench_path=None, mesh=None,
                   calib_batches=None, cushion=None, scales=None):
    install_sigterm_drain()
    reqs = poisson_trace(api, args.trace_seed, args.n_requests, args.rate,
                         prompt_lens=(args.prompt_len, args.prompt_len + 8),
                         budgets=(args.tokens, max(1, args.tokens // 2)))
    eng = ContinuousEngine(api, params, qcfg, n_slots=args.slots,
                           max_seq=args.prompt_len + 8 + args.tokens + 32,
                           cushion=cushion, scales=scales, mesh=mesh,
                           kv_dtype=None if args.kv_dtype == "fp"
                           else args.kv_dtype,
                           calib_batches=calib_batches,
                           prequant=args.prequant,
                           weight_bits=args.weight_bits,
                           paged=args.paged, page_size=args.page_size,
                           n_pages=args.pages,
                           prefix_cache=args.prefix_cache,
                           chunk_tokens=args.chunk_tokens)
    if eng.chunk_auto:
        print(f"[serve] chunked prefill: adaptive budget "
              f"(decode-pressure-scaled, max {eng.chunk_tokens} "
              f"tokens/chunk)")
    elif eng.chunk_tokens:
        print(f"[serve] chunked prefill: {eng.chunk_tokens} tokens/chunk "
              f"(budget bucketed from --chunk-tokens {args.chunk_tokens})")
    if cushion is not None:
        print(f"[serve] serving cushion {eng.cushion_fp[:12]} "
              f"(prefix_len={eng.prefix_len})")
    print(f"[serve] resident weights: "
          f"fp={eng.stats.weight_bytes_fp / 2 ** 20:.1f} MiB "
          f"int8={eng.stats.weight_bytes_int8 / 2 ** 20:.1f} MiB "
          f"int4={eng.stats.weight_bytes_int4 / 2 ** 20:.1f} MiB")
    if args.paged:
        st = eng.stats
        print(f"[serve] paged pool: {st.pages_total} pages x "
              f"{args.page_size} positions, "
              f"{st.pool_bytes / 2 ** 20:.2f} MiB resident "
              f"(cushion refs {st.cushion_page_refs})")
    if bench_path:
        eng.run(reqs)           # warm/compile pass; measure steady state
    outs = eng.run(reqs)
    for o in outs:
        print(f"[serve]   req {o.uid}: slot {o.slot} n={len(o.tokens)} "
              f"TTFT={o.ttft_ms:.1f}ms TPOT={o.tpot_ms:.2f}ms "
              f"latency={o.latency_s * 1e3:.0f}ms")
    if eng.stats.interrupted:
        print(f"[serve] DRAINED: interrupted after {len(outs)} of "
              f"{len(reqs)} requests; live slots completed, queued "
              f"remainder dropped")
    print(f"[serve] final stats: {eng.stats.as_dict()}")
    if args.paged:
        st = eng.stats
        print(f"[serve] page pool: total={st.pages_total} "
              f"free={st.pages_free} shared={st.pages_shared} "
              f"cushion_refs={st.cushion_page_refs} "
              f"prefix_hits={st.prefix_hits} "
              f"prefix_misses={st.prefix_misses} "
              f"positions_exhausted={st.positions_exhausted} "
              f"pool_bytes={st.pool_bytes}")
    if not outs:
        return outs
    total = sum(len(o.tokens) for o in outs)
    span = max(o.finished_s for o in outs) - min(r.arrival_s for r in reqs)
    lat = np.asarray([o.latency_s for o in outs])
    tps = total / max(span, 1e-9)
    occ = eng.stats.occupancy()
    print(f"[serve] continuous: {len(outs)} reqs, {total} tokens, "
          f"{tps:.1f} tok/s, p50={np.percentile(lat, 50) * 1e3:.0f}ms "
          f"p99={np.percentile(lat, 99) * 1e3:.0f}ms occupancy={occ:.2f}")
    if bench_path:
        point = {"mode": "continuous", "arch": args.arch,
                 "quant": args.quant, "prequant": args.prequant,
                 "weight_bits": args.weight_bits,
                 "paged": args.paged, "page_size": args.page_size,
                 "prefix_cache": args.prefix_cache,
                 "kv_dtype": args.kv_dtype, "slots": args.slots,
                 "rate": args.rate, "n_requests": args.n_requests,
                 "tokens_per_s": tps,
                 "p50_latency_s": float(np.percentile(lat, 50)),
                 "p99_latency_s": float(np.percentile(lat, 99)),
                 "occupancy": occ, **eng.stats.as_dict()}
        _append_point(bench_path, point)
    return outs


def run_router(api, params, qcfg, args, bench_path=None, calib_batches=None,
               cushion=None, scales=None):
    """--replicas N: the trace goes through the fault-tolerant replica
    router instead of a single engine. --chaos arms deterministic fault
    injection; rejections, retries, failovers and per-replica health land
    in the printed RouterStats."""
    from repro.distributed.fault_injection import FaultInjector
    from repro.serving.router import ReplicaRouter, RouterConfig

    install_sigterm_drain()
    meshes = None
    if args.tp > 1:
        from repro.launch.mesh import make_replica_meshes
        meshes = make_replica_meshes(args.replicas, args.tp)
        print(f"[serve] {args.replicas} replicas x tp={args.tp} on disjoint "
              f"device groups")
    injector = None
    if args.chaos:
        injector = FaultInjector.parse(args.chaos, seed=args.chaos_seed)
        print(f"[serve] chaos armed: {args.chaos} (seed {args.chaos_seed})")
    reqs = poisson_trace(api, args.trace_seed, args.n_requests, args.rate,
                         prompt_lens=(args.prompt_len, args.prompt_len + 8),
                         budgets=(args.tokens, max(1, args.tokens // 2)))
    router = ReplicaRouter(
        api, params, qcfg, n_replicas=args.replicas,
        cfg=RouterConfig(max_queue=args.max_queue), meshes=meshes,
        n_slots=args.slots, max_seq=args.prompt_len + 8 + args.tokens + 32,
        cushion=cushion, scales=scales,
        kv_dtype=None if args.kv_dtype == "fp" else args.kv_dtype,
        calib_batches=calib_batches, prequant=args.prequant,
        weight_bits=args.weight_bits,
        paged=args.paged, page_size=args.page_size, n_pages=args.pages,
        prefix_cache=args.prefix_cache, chunk_tokens=args.chunk_tokens)
    res = router.run(reqs, injector=injector)
    for o in res.outputs:
        retry = f" attempts={o.attempts}" if o.attempts > 1 else ""
        print(f"[serve]   req {o.uid}: replica {o.replica} slot {o.slot} "
              f"n={len(o.tokens)} TTFT={o.ttft_ms:.1f}ms "
              f"TPOT={o.tpot_ms:.2f}ms "
              f"latency={o.latency_s * 1e3:.0f}ms{retry}")
    for r in res.rejected:
        print(f"[serve]   req {r.uid}: REJECTED ({r.reason})")
    st = res.stats
    print(f"[serve] router: {st.completed}/{st.submitted} completed, "
          f"{st.rejected} rejected, {st.retries} retries, "
          f"{st.failovers} failovers, {st.replica_deaths} deaths, "
          f"queue peak {st.queue_depth_peak}, states "
          f"{[p['state'] for p in st.per_replica]}")
    if st.drained:
        print("[serve] DRAINED: graceful shutdown completed the live slots")
    if res.outputs:
        lat = np.asarray([o.latency_s for o in res.outputs])
        print(f"[serve] p50={np.percentile(lat, 50) * 1e3:.0f}ms "
              f"p99={np.percentile(lat, 99) * 1e3:.0f}ms")
    print(f"[serve] final stats: {st.as_dict()}")
    if bench_path:
        _append_point(bench_path, {
            "mode": "router", "arch": args.arch, "quant": args.quant,
            "replicas": args.replicas, "chaos": args.chaos or "",
            "slots": args.slots, "rate": args.rate,
            "n_requests": args.n_requests, **st.as_dict()})
    return res


def _append_point(path: str, point: dict) -> None:
    hist = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                prev = json.load(f)
            hist = prev if isinstance(prev, list) else [prev]
        except (json.JSONDecodeError, OSError) as e:
            print(f"[serve] WARNING: could not read {path} "
                  f"({e}); starting a fresh trajectory")
    hist.append(point)
    with open(path, "w") as f:
        json.dump(hist, f, indent=1)
    print(f"[serve] bench point -> {path}")


def _chunk_tokens_arg(v: str):
    """--chunk-tokens value: an int budget or 'auto' (adaptive)."""
    if v == "auto":
        return v
    return int(v)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper_tiny")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quant", default="none")
    ap.add_argument("--mode", default="static",
                    choices=["static", "continuous"],
                    help="static: one Engine batch; continuous: Poisson "
                         "trace through the slot-pool scheduler")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4,
                    help="continuous mode: cache-slot pool size")
    ap.add_argument("--rate", type=float, default=8.0,
                    help="continuous mode: Poisson arrival rate (req/s)")
    ap.add_argument("--n-requests", type=int, default=8,
                    help="continuous mode: trace length")
    ap.add_argument("--replicas", type=int, default=1,
                    help="continuous mode: serve through the replica "
                         "router over N data-parallel engine replicas "
                         "(health checks, retries, backpressure, drain)")
    ap.add_argument("--chaos", default=None,
                    help="router mode: comma-separated fault specs "
                         "kind@site:step[:stall_s], e.g. "
                         "crash@replica1.step:12 (kinds: crash, stall, "
                         "heartbeat, interrupt)")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="seed for randomized fault schedules")
    ap.add_argument("--max-queue", type=int, default=64,
                    help="router mode: bounded admission queue size "
                         "(overflow -> explicit queue_full rejection)")
    ap.add_argument("--trace-seed", type=int, default=None,
                    help="seed for the Poisson trace (arrivals, prompts, "
                         "budgets); defaults to --seed. Same seed = "
                         "identical workload replay")
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore params from latest checkpoint")
    ap.add_argument("--cushion", default=None,
                    help="serve the latest tuned-cushion artifact from "
                         "this launch/tune.py --out-dir (fingerprint "
                         "verified at load; stored pt_static scales serve "
                         "directly when --quant pt_static)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel width: shard params (serve rules) "
                         "and the KV pool heads axis over a (data=1, tp=N) "
                         "mesh; works on CPU via forced host devices (set "
                         "automatically at import) and on real accelerator "
                         "meshes alike")
    ap.add_argument("--paged", action="store_true",
                    help="continuous mode: paged KV pool — flat page store "
                         "+ per-slot page tables, the fp cushion held once "
                         "batch-free instead of copied per slot, pages "
                         "allocated on decode appends and returned at "
                         "retirement (serving/paging.py)")
    ap.add_argument("--page-size", type=int, default=64,
                    help="paged mode: positions per KV page (must divide "
                         "max_seq, multiple of 8)")
    ap.add_argument("--pages", type=int, default=None,
                    help="paged mode: physical page count; default sizes "
                         "the pool for the worst case so admission never "
                         "backpressures on pages — pass less to realize "
                         "the memory win on overlapping workloads")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="paged fp pools: content-addressed prompt-stem "
                         "page sharing — repeated stems map the donor's "
                         "pages read-only and only prefill the tail")
    ap.add_argument("--kv-dtype", default="fp", choices=["fp", "int8"],
                    help="KV-cache storage precision (int8 halves decode "
                         "HBM traffic; cushion prefix stays fp; the "
                         "continuous pool calibrates per-slot scales at "
                         "each admission prefill)")
    ap.add_argument("--prequant", action="store_true",
                    help="serve int8-resident weights: calibrate pt_static "
                         "site scales at load, prequantize the param tree "
                         "(1 byte/weight streamed into the W8A8 matmul "
                         "path); requires --quant pt_static")
    ap.add_argument("--weight-bits", type=int, default=8, choices=[8, 4],
                    help="resident weight precision with --prequant: 8 = "
                         "int8 w_int (W8A8), 4 = nibble-packed w_packed "
                         "(W4A8, 0.5 byte/weight through the unpack-in-"
                         "VMEM kernel); activations stay int8 either way")
    ap.add_argument("--calib-batches", type=int, default=2,
                    help="pt_static: number of calibration batches drawn "
                         "from the synthetic pipeline at engine load")
    ap.add_argument("--chunk-tokens", type=_chunk_tokens_arg, default=None,
                    help="chunked admission prefill: per-step token budget "
                         "(bucketed to a power of two); prompts longer "
                         "than one budget prefill one chunk per decode "
                         "step instead of blocking the whole pool — short "
                         "prompts admit between a long prompt's chunks. "
                         "'auto' adapts the budget to decode pressure "
                         "(big chunks when idle, small when slots are "
                         "near-full)")
    ap.add_argument("--bench-json", default=None,
                    help="append a trajectory point to this file")
    args = ap.parse_args(argv)
    if args.chunk_tokens is not None and args.mode != "continuous":
        ap.error("--chunk-tokens requires --mode continuous (chunked "
                 "admission lives in the slot scheduler)")
    if args.prequant and args.quant != "pt_static":
        ap.error("--prequant requires --quant pt_static (int8-resident "
                 "weights serve the per-tensor static deployment path)")
    if args.weight_bits == 4 and not args.prequant:
        ap.error("--weight-bits 4 requires --prequant (the int4-packed "
                 "format only exists as resident serving weights)")
    if (args.replicas > 1 or args.chaos) and args.mode != "continuous":
        ap.error("--replicas/--chaos require --mode continuous (the "
                 "router fronts ContinuousEngine replicas)")
    if args.paged and args.mode != "continuous":
        ap.error("--paged requires --mode continuous (the paged pool "
                 "lives in the slot scheduler)")
    if args.prefix_cache and not args.paged:
        ap.error("--prefix-cache requires --paged (stems are shared at "
                 "page granularity)")
    if args.prefix_cache and args.kv_dtype != "fp":
        ap.error("--prefix-cache shares fp pages only (int8 pages carry "
                 "the donor's per-slot scales)")
    if args.trace_seed is None:
        args.trace_seed = args.seed

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg, dtype="float32")
    api = build(cfg)
    rng = jax.random.PRNGKey(args.seed)
    params = api.init_params(rng)
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir)
        step = ckpt.latest_step()
        if step is not None:
            from repro.optim.adamw import AdamW, constant_lr
            opt_state = AdamW(lr=constant_lr(1e-3)).init(params)
            like = {"params": params, "opt": opt_state._asdict()}
            params = ckpt.restore(step, like=like)["params"]
            print(f"[serve] restored step {step}")

    # pt_static serves the true-int8 deployment path (the one --prequant
    # makes int8-resident); dynamic modes keep the fake-quant fidelity path
    qcfg = QuantConfig(mode=args.quant,
                       true_int8=args.quant == "pt_static")
    mesh = None
    if args.tp > 1:
        from repro.launch.mesh import make_tp_mesh
        mesh = make_tp_mesh(args.tp)
        print(f"[serve] tp={args.tp} mesh over "
              f"{[str(d) for d in mesh.devices.flat]}")

    cushion, art_scales = None, None
    if args.cushion:
        cushion, art_scales, _ = load_cushion_artifact(args.cushion, api)
        if art_scales is not None and args.quant != "pt_static":
            art_scales = None       # stored scales only apply to pt_static

    corpus = SyntheticCorpus(cfg.vocab_size, seed=args.seed)
    pipe = Pipeline(corpus, batch=args.batch, seq_len=args.prompt_len,
                    seed=args.seed + 1)
    calib = None
    if args.quant == "pt_static":
        if art_scales is not None:
            print("[serve] pt_static: serving the artifact's stored scales "
                  f"(calibrated under cushion "
                  f"{art_scales.cushion_fp[:12]}) — no load-time "
                  "calibration")
        else:
            calib = [{k: jnp.asarray(v)
                      for k, v in pipe.get_batch(1000 + i).items()}
                     for i in range(args.calib_batches)]
            print(f"[serve] pt_static: calibrating site scales over "
                  f"{len(calib)} batches at engine load"
                  + (" (under the loaded cushion)" if cushion is not None
                     else ""))

    if args.mode == "continuous":
        if args.replicas > 1 or args.chaos:
            return run_router(api, params, qcfg, args,
                              bench_path=args.bench_json,
                              calib_batches=calib, cushion=cushion,
                              scales=art_scales)
        return run_continuous(api, params, qcfg, args,
                              bench_path=args.bench_json, mesh=mesh,
                              calib_batches=calib, cushion=cushion,
                              scales=art_scales)

    batch = {k: jnp.asarray(v) for k, v in pipe.get_batch(0).items()}

    eng = Engine(api, params, qcfg,
                 max_seq=args.prompt_len + args.tokens + 32,
                 cushion=cushion, scales=art_scales,
                 kv_dtype=None if args.kv_dtype == "fp" else args.kv_dtype,
                 mesh=mesh, calib_batches=calib, prequant=args.prequant,
                 weight_bits=args.weight_bits)
    print(f"[serve] resident weights: "
          f"fp={eng.weight_bytes_fp / 2 ** 20:.1f} MiB "
          f"int8={eng.weight_bytes_int8 / 2 ** 20:.1f} MiB "
          f"int4={eng.weight_bytes_int4 / 2 ** 20:.1f} MiB")
    if args.bench_json:
        eng.generate(batch, args.tokens)     # warm/compile: the recorded
        # point must measure steady-state decode, not scan-loop tracing
    res = eng.generate(batch, args.tokens)
    print(f"[serve] B={args.batch} prompt={args.prompt_len} "
          f"gen={args.tokens} kv={args.kv_dtype} tp={args.tp} "
          f"TTFT={res.ttft_ms:.1f}ms TPOT={res.tpot_ms:.2f}ms")
    print("[serve] sample:", res.tokens[0][:16].tolist())
    if args.bench_json:
        _append_point(args.bench_json, {
            "mode": "static", "arch": args.arch, "quant": args.quant,
            "prequant": args.prequant, "weight_bits": args.weight_bits,
            "kv_dtype": args.kv_dtype,
            "batch": args.batch, "tp": args.tp,
            "prompt_len": args.prompt_len, "tokens": args.tokens,
            "weight_bytes_fp": eng.weight_bytes_fp,
            "weight_bytes_int8": eng.weight_bytes_int8,
            "weight_bytes_int4": eng.weight_bytes_int4,
            "ttft_ms": res.ttft_ms, "tpot_ms": res.tpot_ms})
    return res


if __name__ == "__main__":
    main()
