"""repro.launch"""
