"""Fault-tolerant checkpoint store.

* atomic: write into `<dir>/tmp.<step>`, fsync, rename to `<dir>/step_<n>`
* integrity: sha256 of every shard file recorded in the manifest; verified
  on restore
* keep-k garbage collection
* elastic restore: arrays are stored as host (fully-replicated logical)
  values, so a restart may resume on a different mesh/device count — the
  caller re-device_puts with the new shardings (reshard-on-restore).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = []
    for kp, _ in flat:
        parts = []
        for k in kp:
            if isinstance(k, jax.tree_util.DictKey):
                parts.append(str(k.key))
            elif isinstance(k, jax.tree_util.SequenceKey):
                parts.append(str(k.idx))
            elif isinstance(k, jax.tree_util.GetAttrKey):
                parts.append(k.name)
            else:
                parts.append(str(k))
        keys.append("/".join(parts))
    return keys, [v for _, v in flat], treedef


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> str:
        tmp = os.path.join(self.directory, f"tmp.{step}")
        final = os.path.join(self.directory, f"step_{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        keys, vals, _ = _flatten(tree)
        host_vals = [np.asarray(v) for v in jax.device_get(vals)]
        # npz can't hold ml_dtypes (bf16/fp8): upcast losslessly, restore
        # casts back using the manifest dtype
        arrays = {}
        for i, v in enumerate(host_vals):
            if v.dtype.kind not in "fiub?":
                v = v.astype(np.float32)
            elif v.dtype == np.float16 or str(v.dtype) == "bfloat16":
                v = v.astype(np.float32)
            arrays[f"a{i}"] = v
        shard_path = os.path.join(tmp, "arrays.npz")
        np.savez(shard_path, **arrays)
        manifest = {
            "step": step,
            "keys": keys,
            "dtypes": [str(np.asarray(v).dtype) for v in host_vals],
            "shapes": [list(np.asarray(v).shape) for v in host_vals],
            "sha256": {"arrays.npz": _sha256(shard_path)},
            "extra": extra or {},
        }
        mpath = os.path.join(tmp, "manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)        # atomic publish
        self._gc()
        return final

    # ------------------------------------------------------------------
    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_"):
                # ignore partially-written dirs (no manifest)
                if os.path.exists(os.path.join(self.directory, name,
                                               "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def _load_arrays(self, step: int, verify: bool):
        """(manifest, npz handle) for a step, with integrity verification —
        the shared front half of `restore` / `restore_tree`."""
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        apath = os.path.join(d, "arrays.npz")
        if verify:
            got = _sha256(apath)
            want = manifest["sha256"]["arrays.npz"]
            if got != want:
                raise IOError(f"checkpoint corruption at step {step}: "
                              f"sha256 {got} != {want}")
        return manifest, np.load(apath)

    def restore(self, step: int, like: Any, shardings: Any = None,
                verify: bool = True) -> Any:
        """Restore into the structure of `like`; optionally device_put with
        `shardings` (same treedef) — this is the elastic reshard path."""
        manifest, data = self._load_arrays(step, verify)
        keys, vals, treedef = _flatten(like)
        if keys != manifest["keys"]:
            raise ValueError("checkpoint/param-tree structure mismatch")
        import ml_dtypes  # noqa: F401  (registers bf16 etc. with numpy)
        arrays = [data[f"a{i}"].astype(np.dtype(manifest["dtypes"][i]))
                  for i in range(len(keys))]
        tree = jax.tree_util.tree_unflatten(treedef, arrays)
        if shardings is not None:
            flat_s = treedef.flatten_up_to(shardings)
            arrays = [jax.device_put(a, s) for a, s in zip(arrays, flat_s)]
            tree = jax.tree_util.tree_unflatten(treedef, arrays)
        return tree

    def restore_tree(self, step: int, verify: bool = True):
        """Rebuild the saved pytree as NESTED DICTS purely from the
        manifest — no `like` template. For artifacts whose structure the
        loader can't know statically (cushion artifacts: the kv/state
        subtrees and the optional scales tree are family- and
        configuration-dependent). Manifest keys split on "/" and every
        level restores as a dict (sequence indices and attr names become
        string keys — cushion/scales artifacts are saved as pure nested
        dicts, see calibration.scales_to_plain). Returns (tree, manifest).
        """
        manifest, data = self._load_arrays(step, verify)
        import ml_dtypes  # noqa: F401  (registers bf16 etc. with numpy)
        tree: Dict[str, Any] = {}
        for i, key in enumerate(manifest["keys"]):
            parts = key.split("/")
            node = tree
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = data[f"a{i}"].astype(
                np.dtype(manifest["dtypes"][i]))
        return tree, manifest

    def manifest(self, step: int) -> Dict:
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            return json.load(f)

    # ------------------------------------------------------------------
    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
