"""repro.checkpoint"""
