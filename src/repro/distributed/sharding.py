"""Partition-rule engine and sharding helpers.

Sharding strategy (see DESIGN.md §4):

* weights: FSDP-style 2-D — tensor-parallel dims (heads*d_head, d_ff,
  experts) on ``model``; d_model on ``data``. Replicated across ``pod``
  (pure DP over DCN between pods).
* activations: batch on ``(pod, data)``; head / feature dims on ``model``.
* optimizer state inherits the param specs (ZeRO-1).

Rules are (regex, PartitionSpec-template) pairs matched against the
"/"-joined param path; templates use axis *roles* ("B", "D", "M", None)
resolved against the active mesh (so the same rules serve the single-pod
(data, model) and multi-pod (pod, data, model) meshes).
"""
from __future__ import annotations

import contextlib
import contextvars
import re
from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE_MESH: contextvars.ContextVar[Optional[Mesh]] = \
    contextvars.ContextVar("repro_active_mesh", default=None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    """Activate a mesh for `constrain` hints inside model code."""
    tok = _ACTIVE_MESH.set(mesh)
    try:
        if mesh is not None:
            with mesh:
                yield mesh
        else:
            yield None
    finally:
        _ACTIVE_MESH.reset(tok)


def active_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH.get()


def shard_map_compat(f, mesh, in_specs, out_specs):
    """jax.shard_map across jax versions: the top-level alias (with its
    `check_vma` kwarg) appeared after 0.4.x; older releases expose
    jax.experimental.shard_map with `check_rep` instead."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def _resolve_role(role, mesh: Mesh):
    """Map an axis role to concrete mesh axis name(s). Training meshes name
    the tensor-parallel axis ``model``; serving meshes (launch/mesh.py
    ``make_tp_mesh``) name it ``tp`` — the same "M" role resolves to either,
    so one set of rules serves both worlds."""
    names = mesh.axis_names
    if role is None:
        return None
    if role == "B":                      # batch: all pure-data axes
        return ("pod", "data") if "pod" in names else "data"
    if role == "D":                      # fsdp: data axis only
        return "data"
    if role == "M":                      # tensor parallel
        return "tp" if "tp" in names else "model"
    return role


def spec(*roles) -> Tuple[Any, ...]:
    return tuple(roles)


def to_pspec(roles: Sequence[Any], mesh: Mesh) -> P:
    return P(*[_resolve_role(r, mesh) for r in roles])


def constrain(x: jax.Array, *roles) -> jax.Array:
    """Sharding hint; no-op when no mesh is active (CPU tests). Axes that
    don't divide their mesh extent are dropped to replicated (same
    ``roles_pspec`` rule as the cache layout) — otherwise a hint on e.g. a
    2-kv-head cache at tp=4 would force GSPMD pad-shard/reshard cycles
    against the replicated pool every decode step."""
    mesh = _ACTIVE_MESH.get()
    if mesh is None or mesh.size == 1:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, roles_pspec(roles, x.shape, mesh)))


# ---------------------------------------------------------------------------
# Parameter partition rules
# ---------------------------------------------------------------------------

# (regex over "/".join(path), role template). First match wins. Templates are
# aligned to the *trailing* dims of the array (leading dims — e.g. the stacked
# layer axis from scan — are unsharded).
DEFAULT_RULES: Tuple[Tuple[str, Tuple[Any, ...]], ...] = (
    # embeddings: vocab on model, d_model on data
    (r"(^|/)embed(/w)?$", ("M", "D")),
    (r"(^|/)(lm_)?head(/w)?$", ("D", "M")),
    (r"pos_embed", (None, "D")),
    # attention
    (r"attn/wqkv$", ("D", "M")),
    (r"attn/bqkv$", ("M",)),
    (r"attn/wo$", ("M", "D")),
    # dense / residual MLP
    (r"mlp/w_(gate|up)$", ("D", "M")),
    (r"mlp/w_down$", ("M", "D")),
    # MoE: experts on model, then (d_in, d_out) on (data, -)
    (r"moe/w_(gate|up)$", ("M", "D", None)),
    (r"moe/w_down$", ("M", None, "D")),
    (r"moe/router$", ("D", None)),
    # mamba
    (r"mamba/w_in$", ("D", "M")),
    (r"mamba/w_out$", ("M", "D")),
    (r"mamba/(w_x|conv_w|A_log|D|dt_)", ("M",)),
    # xlstm
    (r"xlstm/w_(qkv|if|o)$", ("D", "M")),
    (r"xlstm/w_proj$", ("M", "D")),
    # norms / scalars: replicated
    (r".*", ()),
)


def serve_rules() -> Tuple[Tuple[str, Tuple[Any, ...]], ...]:
    """Inference partition rules: TP-only (weights replicated across the
    data/pod axes). FSDP ("D"-role) sharding is a *training* memory
    optimization; at decode it forces a per-token all-gather of every
    weight (see EXPERIMENTS.md §Perf, jamba decode iteration)."""
    return tuple((rx, tuple(None if r == "D" else r for r in roles))
                 for rx, roles in DEFAULT_RULES)


def _drop_indivisible(full: Sequence[Any], shape: Tuple[int, ...],
                      mesh: Mesh) -> P:
    """Drop shardings that don't divide (GSPMD would pad; for params and
    cache leaves we prefer exact or replicated on that dim)."""
    fixed = []
    for dim, ax in zip(shape, full):
        if ax is None:
            fixed.append(None)
            continue
        size = np.prod([mesh.shape[a] for a in
                        (ax if isinstance(ax, tuple) else (ax,))])
        fixed.append(ax if dim % int(size) == 0 else None)
    return P(*fixed)


def rules_pspec(path: str, shape: Tuple[int, ...], mesh: Mesh,
                rules=DEFAULT_RULES) -> P:
    # int-resident (prequantized) {w_int | w_packed, w_scale, colsum}
    # leaves: w_int/w_packed shard exactly like their fp parent weight (the
    # rules match the parent path), the (N,)-shaped colsum follows the
    # parent's OUTPUT axis (it is a per-output-column reduction — the
    # zero-point correction must stay local to the shard that owns those
    # columns), and the scalar/group w_scale replicates. For w_packed the
    # contracting axis holds K/2 nibble-pair rows: under the serve rules
    # that axis is unsharded anyway ("D" roles nulled), and under training
    # rules a packed K/2 that no longer divides the mesh axis is dropped to
    # replicated by _drop_indivisible — divisibility is handled, never
    # silently padded.
    path = re.sub(r"/w_(int|packed)$", "", path)
    if path.endswith("/w_scale"):
        return P()
    mcol = re.match(r"^(.*)/colsum$", path)
    if mcol:
        for rx, roles in rules:
            if re.search(rx, mcol.group(1)):
                out_role = roles[-1] if roles else None
                full = (None,) * (len(shape) - 1) \
                    + (_resolve_role(out_role, mesh),)
                return _drop_indivisible(full, shape, mesh)
        return P()
    for rx, roles in rules:
        if re.search(rx, path):
            pads = (None,) * (len(shape) - len(roles))
            full = pads + tuple(_resolve_role(r, mesh) for r in roles)
            return _drop_indivisible(full, shape, mesh)
    return P()


def roles_pspec(roles: Sequence[Any], shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Resolve a role template aligned to the *leading* dims of `shape`
    (cache-leaf convention; trailing dims replicated), dropping axes that
    don't divide — e.g. a KV-heads axis narrower than the tp width falls
    back to replicated instead of GSPMD padding."""
    full = tuple(_resolve_role(r, mesh) for r in roles)
    full = full + (None,) * (len(shape) - len(full))
    return _drop_indivisible(full, shape, mesh)


def cache_shardings(roles: Any, cache: Any, mesh: Mesh) -> Any:
    """NamedShardings for a serving-cache pytree from a family's
    ``cache_roles`` template (models/*.cache_roles: leaf name -> role
    tuple; xlstm nests its state dicts). Leaves without a template entry
    are replicated (tiny scales / cushion blocks / untemplated families)."""
    if isinstance(cache, dict):
        rd = roles if isinstance(roles, dict) else {}
        return {key: cache_shardings(rd.get(key, ()), leaf, mesh)
                for key, leaf in cache.items()}
    rt = roles if isinstance(roles, (tuple, list)) else ()
    return NamedSharding(mesh, roles_pspec(rt, cache.shape, mesh))


def tree_paths(tree: Any) -> Any:
    """Pytree of "/"-joined key paths, same structure as `tree`."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)

    def keystr(kp):
        parts = []
        for k in kp:
            if isinstance(k, jax.tree_util.DictKey):
                parts.append(str(k.key))
            elif isinstance(k, jax.tree_util.SequenceKey):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        return "/".join(parts)
    return jax.tree_util.tree_unflatten(treedef, [keystr(kp) for kp, _ in flat])


def params_shardings(params_shape: Any, mesh: Mesh, rules=DEFAULT_RULES) -> Any:
    """NamedShardings for a (possibly abstract) param pytree."""
    paths = tree_paths(params_shape)
    return jax.tree_util.tree_map(
        lambda p, x: NamedSharding(mesh, rules_pspec(p, x.shape, mesh, rules)),
        paths, params_shape)


def batch_sharding(mesh: Mesh, ndim: int, batch_divisible: bool = True) -> NamedSharding:
    """Leading-axis batch sharding for data batches."""
    roles = ("B",) + (None,) * (ndim - 1)
    if not batch_divisible:
        roles = (None,) * ndim
    return NamedSharding(mesh, to_pspec(roles, mesh))
