"""Deterministic, seedable fault injection for the serving stack.

The router (serving/router.py) threads every unit of replica work through
named *sites* — ``replica{i}.step``, ``replica{i}.admit``,
``replica{i}.heartbeat`` — and calls ``FaultInjector.fire(site)`` at each.
A ``FailPoint`` arms one site at a specific visit count, so a chaos test
can say "kill replica 1 on its 12th decode step" and get the *same*
failure on every run: the chaos suites assert token-for-token parity
against a no-fault run, which is only meaningful when the fault schedule
is reproducible.

Kinds
-----
``crash``      raise ``InjectedFault`` at the site (the router treats it
               as a replica death: mark DEAD, fail the in-flight requests
               over to survivors)
``stall``      sleep ``stall_s`` at the site (trips the router's
               straggler detector -> DEGRADED without killing anything)
``heartbeat``  corrupt the replica's liveness signal: the router stops
               refreshing that replica's heartbeat from this firing on
               (sticky), so heartbeat age grows until the health tracker
               declares it DEAD even though the engine still answers
``interrupt``  raise ``KeyboardInterrupt`` at the site — exercises the
               graceful-drain path (stop admitting, finish live slots)
               deterministically in tests

``at_step`` counts *visits to that site* (the injector keeps a counter per
site), so schedules are independent of wall clock. ``at_step=None`` draws
the firing step uniformly from [0, max_step) with the injector's seeded
RNG — randomized chaos that is still reproducible run-to-run.

CLI specs (``launch/serve.py --chaos``, comma-separated)::

    crash@replica1.step:12            kill replica 1 at its 12th step
    stall@replica0.step:5:0.25        0.25 s stall at step 5
    heartbeat@replica2.heartbeat:8    corrupt replica 2's heartbeat
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

KINDS = ("crash", "stall", "heartbeat", "interrupt")


class InjectedFault(RuntimeError):
    """Raised by a ``crash`` fail-point; carries the site it fired at."""

    def __init__(self, site: str, step: int):
        super().__init__(f"injected crash at {site} (visit {step})")
        self.site = site
        self.step = step


@dataclasses.dataclass
class FailPoint:
    """One armed fault. Fires when ``site``'s visit counter reaches
    ``at_step`` (and every ``every`` visits after that, up to ``count``
    total firings, for recurring faults)."""
    site: str
    kind: str = "crash"
    at_step: Optional[int] = 0      # None -> drawn from the injector's RNG
    stall_s: float = 0.1
    every: Optional[int] = None     # recurring period after first firing
    count: int = 1                  # max total firings
    max_step: int = 64              # RNG range when at_step is None
    fired: int = dataclasses.field(default=0, compare=False)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fail-point kind {self.kind!r}; "
                             f"expected one of {KINDS}")

    def should_fire(self, step: int) -> bool:
        if self.fired >= self.count or self.at_step is None:
            return False
        if step == self.at_step:
            return True
        return (self.every is not None and step > self.at_step
                and (step - self.at_step) % self.every == 0)


class FaultInjector:
    """Holds armed ``FailPoint``s and per-site visit counters.

    ``fire(site)`` increments the site's counter, then applies every
    matching point: ``crash``/``interrupt`` raise, ``stall`` sleeps, and
    non-raising kinds are returned as a list of kind strings for the
    caller to interpret (the router uses ``"heartbeat"`` to stop
    refreshing that replica's liveness signal). A fresh injector (or
    ``reset()``) replays the identical schedule — determinism is the whole
    point."""

    def __init__(self, points: Sequence[FailPoint] = (), seed: int = 0):
        self.points = list(points)
        self.seed = seed
        rng = np.random.RandomState(seed)
        for p in self.points:
            if p.at_step is None:   # seeded randomized schedule
                p.at_step = int(rng.randint(0, max(1, p.max_step)))
        self.counters: Dict[str, int] = {}
        self.log: List[tuple] = []      # (site, visit, kind) firing history

    def add(self, point: FailPoint) -> "FaultInjector":
        if point.at_step is None:
            rng = np.random.RandomState(self.seed + len(self.points))
            point.at_step = int(rng.randint(0, max(1, point.max_step)))
        self.points.append(point)
        return self

    def reset(self) -> None:
        """Rearm every point and zero the visit counters (replay the same
        schedule in a second run)."""
        self.counters = {}
        self.log = []
        for p in self.points:
            p.fired = 0

    def fire(self, site: str, sleep=time.sleep) -> List[str]:
        """Visit ``site``: apply every armed point that matches. Raises for
        ``crash``/``interrupt``; returns the non-raising kinds fired."""
        step = self.counters.get(site, 0)
        self.counters[site] = step + 1
        actions: List[str] = []
        for p in self.points:
            if p.site != site or not p.should_fire(step):
                continue
            p.fired += 1
            self.log.append((site, step, p.kind))
            if p.kind == "crash":
                raise InjectedFault(site, step)
            if p.kind == "interrupt":
                raise KeyboardInterrupt(f"injected interrupt at {site}")
            if p.kind == "stall":
                sleep(p.stall_s)
            actions.append(p.kind)
        return actions

    @staticmethod
    def parse(spec: str, seed: int = 0) -> "FaultInjector":
        """Build an injector from a ``--chaos`` CLI spec: comma-separated
        ``kind@site:step[:stall_s]`` entries (see module docstring)."""
        points = []
        for entry in spec.split(","):
            entry = entry.strip()
            if not entry:
                continue
            try:
                kind, rest = entry.split("@", 1)
                parts = rest.split(":")
                site = parts[0]
                at_step = int(parts[1]) if len(parts) > 1 else 0
                stall = float(parts[2]) if len(parts) > 2 else 0.1
            except (ValueError, IndexError) as e:
                raise ValueError(
                    f"bad --chaos entry {entry!r} (want "
                    f"kind@site:step[:stall_s]): {e}") from None
            points.append(FailPoint(site=site, kind=kind, at_step=at_step,
                                    stall_s=stall))
        return FaultInjector(points, seed=seed)
