"""Collective-communication optimizations.

`compressed_psum`: int8-quantized gradient all-reduce — the paper's
per-tensor-static-quantization insight applied to *training* comms: one
fp32 scale per tensor (one tiny all-reduce) plus an int8 payload cuts
DCN/pod-axis gradient traffic ~4x vs fp32 (~2x vs bf16).

`dp_train_step_compressed`: a shard_map data-parallel step using it.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


from repro.distributed.sharding import shard_map_compat  # noqa: F401  (canonical home; re-exported for existing callers)


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """All-reduce-mean with int8 payload + per-tensor fp32 scale.

    1. all-reduce(max |x|)  — scalar
    2. quantize to int8 symmetric with that global scale
    3. all-reduce int32 accumulate, dequantize, divide by world size
    """
    n = jax.lax.psum(1, axis_name)
    amax = jax.lax.pmax(jnp.max(jnp.abs(x.astype(jnp.float32))), axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    xq = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                  ).astype(jnp.int8)
    acc = jax.lax.psum(xq.astype(jnp.int32), axis_name)
    return (acc.astype(jnp.float32) * scale / n).astype(x.dtype)


def dp_train_step_compressed(grad_fn: Callable, mesh: Mesh,
                             axis_name: str = "data"):
    """Data-parallel gradient computation with compressed all-reduce.

    grad_fn(params, batch) -> (loss, grads) computed on the local shard;
    params replicated, batch split along `axis_name`. Returns a callable
    (params, batch) -> (loss_mean, grads_mean) with int8 gradient comms.
    """
    def local(params, batch):
        loss, grads = grad_fn(params, batch)
        loss = jax.lax.pmean(loss, axis_name)
        grads = jax.tree_util.tree_map(
            lambda g: compressed_psum(g, axis_name), grads)
        return loss, grads

    batch_spec = P(axis_name)
    return shard_map_compat(local, mesh, in_specs=(P(), batch_spec),
                            out_specs=(P(), P()))


def collective_bytes_of_hlo(hlo_text: str) -> dict:
    """Parse optimized HLO, summing result-shape bytes of every collective
    op — the §Roofline collective term source."""
    import re
    dtype_bytes = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8,
                   "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1,
                   "u8": 1, "pred": 1, "c64": 8, "f8e4m3fn": 1,
                   "f8e5m2": 1}
    ops = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
           "collective-permute")
    totals = {op: 0 for op in ops}
    counts = {op: 0 for op in ops}
    # e.g.:  %all-gather.1 = bf16[8,128,2048]{...} all-gather(...)
    pat = re.compile(
        r"=\s+(?:\()?([a-z0-9]+)\[([0-9,]*)\][^)=]*?\s("
        + "|".join(ops) + r")(?:-start|-done)?\(")
    for m in pat.finditer(hlo_text):
        dt, shape_s, op = m.group(1), m.group(2), m.group(3)
        if dt == "tuple":
            continue
        nelem = 1
        if shape_s:
            for d in shape_s.split(","):
                nelem *= int(d)
        totals[op] += nelem * dtype_bytes.get(dt, 4)
        counts[op] += 1
    totals["total"] = sum(totals[o] for o in ops)
    totals["counts"] = counts
    return totals
