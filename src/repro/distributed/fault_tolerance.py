"""Fault tolerance: health tracking shared by the training supervisor and
the serving replica router.

At 1000+ nodes (training) or N replicas (serving), failures are routine.
Two consumers share the machinery here:

* ``Supervisor`` wraps training-step execution with (a) retry +
  restore-from-checkpoint on failure — counting *consecutive* failures
  (a long run accumulating occasional recovered incidents must not exhaust
  the budget) with capped exponential backoff between restore attempts,
  (b) per-step heartbeat timing with straggler detection, and
  (c) deterministic data-pipeline replay from the checkpointed step.
* ``HealthTracker`` is the per-worker health-state machine the serving
  router (serving/router.py) keeps per replica: heartbeat age + consecutive
  error count + straggler detection fold into one of three states —

      HEALTHY   fresh heartbeat, no outstanding errors, normal step times
      DEGRADED  recoverable trouble: an error since the last success, a
                straggling step, or a heartbeat older than half the
                timeout — still dispatchable, but only when no healthy
                peer has capacity
      DEAD      crash (``mark_dead``), ``dead_after_errors`` consecutive
                errors, or heartbeat age past the timeout — never
                dispatched again; its in-flight work fails over

  States are *computed* from the counters (except ``mark_dead``, which is
  sticky), so a replica whose heartbeat resumes before the timeout recovers
  to HEALTHY without special-case code.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional

from repro.checkpoint.store import CheckpointManager

HEALTHY = "HEALTHY"
DEGRADED = "DEGRADED"
DEAD = "DEAD"


class HealthTracker:
    """Per-worker health-state machine (see module docstring).

    ``record_step(dt, now)`` reports a successful unit of work: it clears
    the consecutive-error count, refreshes the heartbeat, and feeds the
    straggler detector (step time > ``straggler_factor`` x rolling median
    over ``window`` steps, armed after ``min_history`` observations).
    ``record_error(now)`` reports a recoverable failure. ``mark_dead`` is
    the terminal transition (crash / injected kill) and is sticky.
    """

    def __init__(self, heartbeat_timeout_s: float = 10.0,
                 dead_after_errors: int = 3, straggler_factor: float = 3.0,
                 window: int = 32, min_history: int = 8):
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.dead_after_errors = dead_after_errors
        self.straggler_factor = straggler_factor
        self.min_history = min_history
        self.times: deque = deque(maxlen=window)
        self.stragglers: List[Any] = []     # labels passed to record_step
        self.consecutive_errors = 0
        self.errors = 0                      # lifetime (reporting only)
        self.last_beat: Optional[float] = None
        self.dead_reason: Optional[str] = None
        self._straggling = False             # last step was flagged

    # -- reporting ------------------------------------------------------

    def beat(self, now: float) -> None:
        self.last_beat = now

    def record_step(self, dt: float, now: float, label: Any = None,
                    beat: bool = True) -> bool:
        """Report a successful step taking ``dt`` seconds. Returns True if
        the step was flagged as a straggler. ``beat=False`` records the
        timing without refreshing the heartbeat — the router uses it for a
        replica whose liveness signal is corrupted (chaos ``heartbeat``
        faults): the engine still answers, but its heartbeat ages until the
        timeout declares it DEAD."""
        self.consecutive_errors = 0
        flagged = False
        if len(self.times) >= self.min_history:
            med = sorted(self.times)[len(self.times) // 2]
            if dt > self.straggler_factor * med:
                self.stragglers.append(label)
                flagged = True
        self._straggling = flagged
        self.times.append(dt)
        if beat:
            self.beat(now)
        return flagged

    def record_error(self, now: float) -> None:
        self.consecutive_errors += 1
        self.errors += 1
        self.beat(now)      # an error is still a sign of life

    def mark_dead(self, reason: str) -> None:
        self.dead_reason = reason

    # -- state ----------------------------------------------------------

    def heartbeat_age(self, now: float) -> float:
        return 0.0 if self.last_beat is None else max(0.0,
                                                      now - self.last_beat)

    def state(self, now: float) -> str:
        if (self.dead_reason is not None
                or self.consecutive_errors >= self.dead_after_errors
                or self.heartbeat_age(now) > self.heartbeat_timeout_s):
            return DEAD
        if (self.consecutive_errors > 0 or self._straggling
                or self.heartbeat_age(now) > self.heartbeat_timeout_s / 2):
            return DEGRADED
        return HEALTHY


@dataclasses.dataclass
class SupervisorReport:
    completed_steps: int
    failures: int
    restores: int
    stragglers: List[int]
    step_times: List[float]


class Supervisor:
    """Training-loop retry/restore wrapper.

    The retry budget is *consecutive*: ``failures`` stays a lifetime
    counter for the report, but only ``max_retries`` failures in a row
    (without an intervening successful step) exhaust the budget — a long
    run with occasional recovered incidents never raises. Between restore
    attempts the supervisor sleeps ``backoff_base_s * 2**(k-1)`` (capped at
    ``backoff_cap_s``) so a flapping node is not hammered with restores.
    """

    def __init__(self, ckpt: CheckpointManager, save_every: int = 50,
                 max_retries: int = 3, straggler_factor: float = 3.0,
                 window: int = 32, backoff_base_s: float = 0.05,
                 backoff_cap_s: float = 2.0):
        self.ckpt = ckpt
        self.save_every = save_every
        self.max_retries = max_retries
        self.backoff_base_s = backoff_base_s
        self.backoff_cap_s = backoff_cap_s
        self.health = HealthTracker(straggler_factor=straggler_factor,
                                    window=window)
        self.failures = 0        # lifetime (reported)
        self.restores = 0

    @property
    def times(self) -> deque:
        return self.health.times

    @property
    def stragglers(self) -> List[int]:
        return self.health.stragglers

    def run(self, state: Any, step0: int, n_steps: int,
            do_step: Callable[[Any, int], Any],
            make_fresh_state: Optional[Callable[[], Any]] = None,
            on_metrics: Optional[Callable[[int, Dict], None]] = None
            ) -> tuple:
        """Run steps [step0, step0+n_steps) with retry/restore. `do_step`
        may raise; we back off, restore the latest checkpoint and replay."""
        step = step0
        end = step0 + n_steps
        while step < end:
            t0 = time.perf_counter()
            try:
                state, metrics = do_step(state, step)
            except Exception:  # noqa: BLE001 — any step failure
                self.failures += 1
                self.health.record_error(time.perf_counter())
                latest = self.ckpt.latest_step()
                if (latest is None
                        or self.health.consecutive_errors > self.max_retries):
                    raise
                # capped exponential backoff: 1st retry waits base, then 2x
                backoff = min(self.backoff_cap_s, self.backoff_base_s
                              * 2 ** (self.health.consecutive_errors - 1))
                if backoff > 0:
                    time.sleep(backoff)
                state = self.ckpt.restore(latest, like=state)
                self.restores += 1
                step = latest  # deterministic pipeline replays from here
                continue
            dt = time.perf_counter() - t0
            self.health.record_step(dt, time.perf_counter(), label=step)
            if on_metrics:
                on_metrics(step, metrics)
            step += 1
            if step % self.save_every == 0 or step == end:
                self.ckpt.save(step, state, extra={"metrics": {
                    k: float(v) for k, v in metrics.items()}})
        report = SupervisorReport(
            completed_steps=step - step0, failures=self.failures,
            restores=self.restores, stragglers=list(self.stragglers),
            step_times=list(self.times))
        return state, report
