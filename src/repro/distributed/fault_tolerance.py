"""Fault-tolerance supervisor for the training loop.

At 1000+ nodes, failures are routine: the supervisor wraps step execution
with (a) retry + restore-from-checkpoint on failure, (b) per-step heartbeat
timing with straggler detection (step time > `straggler_factor` x rolling
median flags the step; on real pods this triggers hot-spare swap — here it
is recorded and surfaced), and (c) deterministic data-pipeline replay from
the checkpointed step (elastic: the restore path re-device_puts onto
whatever mesh the restarted job has).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional

from repro.checkpoint.store import CheckpointManager


@dataclasses.dataclass
class SupervisorReport:
    completed_steps: int
    failures: int
    restores: int
    stragglers: List[int]
    step_times: List[float]


class Supervisor:
    def __init__(self, ckpt: CheckpointManager, save_every: int = 50,
                 max_retries: int = 3, straggler_factor: float = 3.0,
                 window: int = 32):
        self.ckpt = ckpt
        self.save_every = save_every
        self.max_retries = max_retries
        self.straggler_factor = straggler_factor
        self.times: deque = deque(maxlen=window)
        self.stragglers: List[int] = []
        self.failures = 0
        self.restores = 0

    def run(self, state: Any, step0: int, n_steps: int,
            do_step: Callable[[Any, int], Any],
            make_fresh_state: Optional[Callable[[], Any]] = None,
            on_metrics: Optional[Callable[[int, Dict], None]] = None
            ) -> tuple:
        """Run steps [step0, step0+n_steps) with retry/restore. `do_step`
        may raise; we restore the latest checkpoint and replay."""
        step = step0
        end = step0 + n_steps
        while step < end:
            t0 = time.perf_counter()
            try:
                state, metrics = do_step(state, step)
            except Exception as e:  # noqa: BLE001 — any step failure
                self.failures += 1
                latest = self.ckpt.latest_step()
                if latest is None or self.failures > self.max_retries:
                    raise
                state = self.ckpt.restore(latest, like=state)
                self.restores += 1
                step = latest  # deterministic pipeline replays from here
                continue
            dt = time.perf_counter() - t0
            if len(self.times) >= 8:
                med = sorted(self.times)[len(self.times) // 2]
                if dt > self.straggler_factor * med:
                    self.stragglers.append(step)
            self.times.append(dt)
            if on_metrics:
                on_metrics(step, metrics)
            step += 1
            if step % self.save_every == 0 or step == end:
                self.ckpt.save(step, state, extra={"metrics": {
                    k: float(v) for k, v in metrics.items()}})
        report = SupervisorReport(
            completed_steps=step - step0, failures=self.failures,
            restores=self.restores, stragglers=list(self.stragglers),
            step_times=list(self.times))
        return state, report
