"""repro.distributed"""
