"""repro: CushionCache (EMNLP 2024) — production-grade multi-pod JAX
framework for activation-quantizable LLM training and serving."""

__version__ = "1.0.0"
