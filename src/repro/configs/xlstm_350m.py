"""xlstm-350m [ssm]: 24L d_model=1024 4H (GQA kv=4) d_ff=0 vocab=50304 —
sLSTM + mLSTM blocks. [arXiv:2405.04517; unverified]"""
from repro.configs.base import Family, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family=Family.SSM,
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    ssm=SSMConfig(kind="mlstm", expand=2, mlstm_every=2),
    max_seq_len=524288,
)
