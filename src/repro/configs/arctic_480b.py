"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128e top-2 + dense residual. [hf:Snowflake/snowflake-arctic-base; hf]"""
from repro.configs.base import Family, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family=Family.MOE,
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    moe=MoEConfig(num_experts=128, top_k=2, dense_residual_ff=4864),
    max_seq_len=524288,
)
