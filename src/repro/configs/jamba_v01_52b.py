"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2 — Mamba+attn 1:7 interleave, MoE.
[arXiv:2403.19887; hf]"""
from repro.configs.base import Family, HybridConfig, ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family=Family.HYBRID,
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    moe=MoEConfig(num_experts=16, top_k=2),
    ssm=SSMConfig(kind="mamba", d_state=16, d_conv=4, expand=2),
    hybrid=HybridConfig(period=8, attn_at=(3,), moe_every=2, moe_offset=1),
    max_seq_len=524288,
)
