"""internvl2-26b [vlm]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553
— InternViT (stub frontend) + InternLM2 backbone. [arXiv:2404.16821; hf]"""
from repro.configs.base import Family, ModelConfig, VLMConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family=Family.VLM,
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    vlm=VLMConfig(num_patches=1024, frontend="stub"),
    max_seq_len=65536,
)
