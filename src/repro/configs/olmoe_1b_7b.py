"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (GQA kv=16) d_ff=1024 vocab=50304,
MoE 64e top-8. [arXiv:2409.02060; hf]"""
from repro.configs.base import Family, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family=Family.MOE,
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    moe=MoEConfig(num_experts=64, top_k=8),
    max_seq_len=524288,
)
