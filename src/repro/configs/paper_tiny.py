"""paper_tiny: a paper-faithful llama-style tiny LM (~10M params) used to
validate the paper's claims end-to-end on CPU (train -> calibrate -> greedy
search -> prefix tune -> quantized eval)."""
from repro.configs.base import Family, ModelConfig

CONFIG = ModelConfig(
    name="paper_tiny",
    family=Family.DENSE,
    n_layers=4,
    d_model=256,
    n_heads=8,
    n_kv_heads=4,
    d_head=32,
    d_ff=768,
    vocab_size=512,
    max_seq_len=1024,
    qkv_bias=True,   # needed by the outlier-planting surgery (query bias
                     # gives all queries a consistent sink-seeking direction)
    dtype="float32",
)
