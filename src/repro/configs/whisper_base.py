"""whisper-base [audio]: 6L d_model=512 8H (GQA kv=8) d_ff=2048 vocab=51865 —
enc-dec, conv frontend (stub). [arXiv:2212.04356; unverified]"""
from repro.configs.base import EncDecConfig, Family, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family=Family.ENCDEC,
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    norm="layernorm",
    act="gelu",
    gated_mlp=False,
    encdec=EncDecConfig(encoder_layers=6, encoder_seq=1500, frontend="stub"),
    max_seq_len=65536,
)
