"""Config system: every architecture is a `ModelConfig`; experiments are
`RunConfig`s composing model + parallelism + quantization + cushion settings.

Configs are plain frozen dataclasses so they are hashable (usable as jit
static args) and serializable.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Tuple


class Family(str, enum.Enum):
    DENSE = "dense"          # llama-style decoder-only
    MOE = "moe"              # top-k routed experts
    SSM = "ssm"             # xLSTM (mLSTM/sLSTM blocks)
    HYBRID = "hybrid"        # jamba: mamba + attention interleave (+ MoE)
    ENCDEC = "encdec"        # whisper-style encoder-decoder
    VLM = "vlm"              # ViT frontend (stub) + LM backbone


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    # Arctic-style: dense FFN residual branch in parallel with the MoE branch.
    dense_residual_ff: int = 0
    # Router options
    router_jitter: float = 0.0
    load_balance_coef: float = 0.01
    # GShard capacity factor; tokens over capacity are dropped (pass through
    # the residual). Set high for dropless behaviour in tests.
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Parameters for recurrent blocks (Mamba / xLSTM)."""
    kind: str = "mamba"          # "mamba" | "mlstm" | "slstm"
    d_state: int = 16            # mamba state size
    d_conv: int = 4              # causal conv width
    expand: int = 2              # inner expansion factor
    # xLSTM: ratio pattern of mLSTM:sLSTM blocks, e.g. (1, 0) = all mLSTM
    mlstm_every: int = 2         # 1 of every `mlstm_every` blocks is sLSTM


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Jamba-style interleave: in every `period` layers, layers whose index %
    period is in `attn_at` are attention; others are Mamba. MoE applied on
    layers where index % moe_every == moe_offset."""
    period: int = 8
    attn_at: Tuple[int, ...] = (3,)
    moe_every: int = 2
    moe_offset: int = 1


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    encoder_layers: int = 6
    encoder_seq: int = 1500        # whisper: 30s audio -> 1500 frames
    frontend: str = "stub"         # precomputed frame embeddings


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    num_patches: int = 1024
    frontend: str = "stub"         # precomputed patch embeddings


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                  # 0 -> d_model // n_heads
    qkv_bias: bool = False           # qwen-style
    tie_embeddings: bool = False
    norm: str = "rmsnorm"            # "rmsnorm" | "layernorm"
    act: str = "silu"                # "silu" (gated) | "gelu" (dense ff)
    gated_mlp: bool = True
    rope_theta: float = 10000.0
    max_seq_len: int = 8192
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    dtype: str = "bfloat16"          # activation/param compute dtype

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // self.n_heads

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def param_count(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        d, L, V = self.d_model, self.n_layers, self.vocab_size
        hd = self.head_dim
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        if self.gated_mlp:
            mlp_dense = 3 * d * self.d_ff
        else:
            mlp_dense = 2 * d * self.d_ff
        emb = V * d * (1 if self.tie_embeddings else 2)

        if self.family == Family.MOE:
            assert self.moe is not None
            mlp = self.moe.num_experts * mlp_dense + d * self.moe.num_experts
            if self.moe.dense_residual_ff:
                mlp += 3 * d * self.moe.dense_residual_ff
            return L * (attn + mlp + 2 * d) + emb
        if self.family == Family.SSM:
            # xLSTM: qkv-ish projections + gates, rough
            inner = d * (self.ssm.expand if self.ssm else 2)
            blk = 4 * d * inner + 2 * d
            return L * blk + emb
        if self.family == Family.HYBRID:
            assert self.hybrid is not None and self.ssm is not None
            h = self.hybrid
            n_attn = L * len(h.attn_at) // h.period
            n_mamba = L - n_attn
            n_moe = L // h.moe_every
            n_densemlp = L - n_moe
            inner = self.d_model * self.ssm.expand
            mamba = 2 * d * inner + inner * (2 * self.ssm.d_state + 1) \
                + inner * self.ssm.d_conv + inner * d
            moe_mlp = self.moe.num_experts * mlp_dense + d * self.moe.num_experts \
                if self.moe else mlp_dense
            return (n_attn * attn + n_mamba * mamba + n_moe * moe_mlp
                    + n_densemlp * mlp_dense + L * 2 * d + emb)
        if self.family == Family.ENCDEC:
            assert self.encdec is not None
            enc = self.encdec.encoder_layers * (attn + mlp_dense + 2 * d)
            dec = L * (2 * attn + mlp_dense + 3 * d)   # self + cross attn
            return enc + dec + emb
        # DENSE / VLM backbone
        return L * (attn + mlp_dense + 2 * d) + emb

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only top-k experts)."""
        if self.family not in (Family.MOE, Family.HYBRID) or self.moe is None:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        mlp_dense = (3 if self.gated_mlp else 2) * d * self.d_ff
        full = self.param_count()
        if self.family == Family.MOE:
            inactive = L * (self.moe.num_experts - self.moe.top_k) * mlp_dense
        else:
            n_moe = L // self.hybrid.moe_every
            inactive = n_moe * (self.moe.num_experts - self.moe.top_k) * mlp_dense
        return full - inactive


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Quantization scheme configuration (paper §3, §5.1)."""
    mode: str = "none"       # none|pt_static|pt_dynamic|ptoken_dynamic
    w_bits: int = 8
    a_bits: int = 8
    w_group: int = 128       # group-wise symmetric weight quant (0 = per-channel)
    symmetric_w: bool = True
    symmetric_a: bool = False  # paper: asymmetric activations
    smoothquant: bool = False
    smooth_alpha: float = 0.8  # paper's migration strength
    true_int8: bool = False    # int8 dot_general (serving/roofline path) vs fake-quant


@dataclasses.dataclass(frozen=True)
class CushionConfig:
    """CushionCache discovery configuration (paper §4)."""
    max_prefix_len: int = 16
    tau: float = 0.5                 # greedy early-stop threshold, eq. (10)
    sample_len: int = 512            # calibration sample length n
    n_candidates: int = 256          # embedding-table candidates per greedy step
    seed_tokens: Tuple[int, ...] = ()  # nonsemantic init (<bos>, \n)
    lam: float = 0.01                # λ for L_pred + λ·L_q, eq. (11)
    tune_steps: int = 200
    tune_lr: float = 1e-3
    log_every: int = 10              # tuning metric host-sync cadence (steps
                                     # per blocking device->host transfer)


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    multi_pod: bool = False
    dp: int = 16
    tp: int = 16
    pods: int = 2
    remat: bool = True
    zero1: bool = True
    grad_compress: bool = False   # int8 gradient all-reduce on DP/pod axes
    use_pallas: bool = False      # route matmuls through Pallas kernels (TPU)


@dataclasses.dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    quant: QuantConfig = QuantConfig()
    cushion: CushionConfig = CushionConfig()
    parallel: ParallelConfig = ParallelConfig()
    seq_len: int = 2048
    global_batch: int = 8
    lr: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    train_steps: int = 1000
    grad_clip: float = 1.0
    seed: int = 0


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A smoke-test-sized model of the same family (small layers/width/experts,
    tiny embedding table) used by per-arch smoke tests on CPU."""
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=min(cfg.n_layers, 4),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_head=16,
        d_ff=(128 if cfg.d_ff else 0),
        vocab_size=256,
        max_seq_len=512,
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=min(cfg.moe.num_experts, 8),
            top_k=min(cfg.moe.top_k, 2),
            dense_residual_ff=128 if cfg.moe.dense_residual_ff else 0,
            capacity_factor=64.0)  # dropless at smoke scale
    if cfg.hybrid is not None:
        kw["n_layers"] = cfg.hybrid.period  # one full period
    if cfg.encdec is not None:
        kw["encdec"] = dataclasses.replace(
            cfg.encdec, encoder_layers=2, encoder_seq=32)
    if cfg.vlm is not None:
        kw["vlm"] = dataclasses.replace(cfg.vlm, num_patches=16)
    kw.update(overrides)
    return dataclasses.replace(cfg, **kw)
