"""Architecture registry: --arch <id> resolves here."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (  # noqa: F401
    CushionConfig,
    EncDecConfig,
    Family,
    HybridConfig,
    ModelConfig,
    MoEConfig,
    ParallelConfig,
    QuantConfig,
    RunConfig,
    SSMConfig,
    reduced,
)

_ARCH_MODULES: Dict[str, str] = {
    "arctic-480b": "repro.configs.arctic_480b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "xlstm-350m": "repro.configs.xlstm_350m",
    "whisper-base": "repro.configs.whisper_base",
    "jamba-v0.1-52b": "repro.configs.jamba_v01_52b",
    "internvl2-26b": "repro.configs.internvl2_26b",
    "stablelm-3b": "repro.configs.stablelm_3b",
    "smollm-360m": "repro.configs.smollm_360m",
    "qwen1.5-0.5b": "repro.configs.qwen15_05b",
    "deepseek-67b": "repro.configs.deepseek_67b",
    "paper_tiny": "repro.configs.paper_tiny",
}

ARCH_IDS = [a for a in _ARCH_MODULES if a != "paper_tiny"]


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch]).CONFIG


# Assigned input shapes (LM shapes: seq_len x global_batch).
SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}

# long_500k needs sub-quadratic attention: only SSM/hybrid archs run it
# (see DESIGN.md §6). Everyone runs the other three.
LONG_CONTEXT_ARCHS = ("xlstm-350m", "jamba-v0.1-52b")


def cell_is_applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_CONTEXT_ARCHS
    return True
