"""Distributed training loop: pjit train step with FSDP/TP shardings,
gradient accumulation (scan over microbatches), remat-in-scan, ZeRO-1
optimizer states, and the quantization-aware-training path (fake-quant
forward) used by the paper's prefix tuning at framework scale.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import QuantConfig, RunConfig
from repro.distributed import sharding as SH
from repro.models.registry import ModelAPI, build
from repro.optim.adamw import AdamW, AdamWState, cosine_lr


@dataclasses.dataclass
class TrainState:
    params: Any
    opt: AdamWState
    step: int


def make_optimizer(run: RunConfig) -> AdamW:
    return AdamW(lr=cosine_lr(run.lr, run.warmup_steps, run.train_steps),
                 weight_decay=run.weight_decay, grad_clip=run.grad_clip)


def make_train_step(api: ModelAPI, run: RunConfig, opt: AdamW,
                    microbatches: int = 1,
                    cushion: Any = None, scales: Any = None
                    ) -> Callable:
    """Builds train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). With microbatches > 1, the global batch is split and gradients
    accumulated in a scan (memory-bound shapes)."""
    qcfg = run.quant

    def loss(params, batch):
        l, aux = api.loss_fn(params, batch, qcfg, cushion=cushion,
                             scales=scales, remat=run.parallel.remat)
        return l, aux

    def grads_of(params, batch):
        (l, aux), g = jax.value_and_grad(loss, has_aux=True)(params, batch)
        return l, aux, g

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            l, aux, g = grads_of(params, batch)
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])
            mb = jax.tree_util.tree_map(split, batch)

            def body(carry, b):
                acc, lsum = carry
                li, _, gi = grads_of(params, b)
                acc = jax.tree_util.tree_map(jnp.add, acc, gi)
                return (acc, lsum + li), ()

            zeros = jax.tree_util.tree_map(
                lambda a: jnp.zeros(a.shape, jnp.float32), params)
            (g, lsum), _ = jax.lax.scan(body, (zeros, jnp.zeros(())), mb)
            g = jax.tree_util.tree_map(lambda a: a / microbatches, g)
            l = lsum / microbatches
            aux = {}
        params, opt_state, om = opt.update(g, opt_state, params)
        metrics = {"loss": l, **{k: v for k, v in om.items()}}
        if isinstance(aux, dict) and "ce" in aux:
            metrics["ce"] = aux["ce"]
        return params, opt_state, metrics

    return train_step


def replicated_shardings(tree: Any, mesh: Mesh) -> Any:
    """Every leaf fully replicated across `mesh` — the layout for small
    trainable trees (the cushion KV block and its optimizer moments) that
    ride a data axis for batch parallelism only."""
    rep = NamedSharding(mesh, P())
    return jax.tree_util.tree_map(lambda _: rep, tree)


def shard_update_step(step_fn: Callable, mesh: Mesh, var_shardings: Any,
                      opt_shardings: Any, batch_like: Any = None):
    """jit-compile an ``(vars, opt_state, batch) -> (vars, opt_state,
    metrics)`` update step for `mesh`: carried state in/out under the given
    shardings and DONATED (compile-once, no per-step copies), batch leaves
    split on the "data" axis when `batch_like` (arrays or ShapeDtypeStructs;
    only ndim matters) is given. Shared by `shard_train_step` (FSDP param
    shardings) and `cushioncache.prefix_tune` (replicated cushion)."""
    if batch_like is None:
        b_sh = None
    else:
        b_sh = jax.tree_util.tree_map(
            lambda x: SH.batch_sharding(mesh, x.ndim), batch_like)
    return jax.jit(
        step_fn,
        in_shardings=(var_shardings, opt_shardings, b_sh),
        out_shardings=(var_shardings, opt_shardings, None),
        donate_argnums=(0, 1))


def shard_train_step(api: ModelAPI, run: RunConfig, opt: AdamW, mesh: Mesh,
                     params_abstract: Any, microbatches: int = 1,
                     cushion: Any = None, scales: Any = None):
    """pjit-compile the train step for `mesh` with the partition rules.
    Returns (jitted_fn, param_shardings, batch_shardings)."""
    p_sh = SH.params_shardings(params_abstract, mesh)
    opt_abstract = jax.eval_shape(opt.init, params_abstract)
    o_sh = AdamWState(
        step=NamedSharding(mesh, P()),
        mu=SH.params_shardings(opt_abstract.mu, mesh),
        nu=SH.params_shardings(opt_abstract.nu, mesh))
    step_fn = make_train_step(api, run, opt, microbatches, cushion, scales)
    fn = shard_update_step(step_fn, mesh, p_sh, o_sh)
    return fn, p_sh, o_sh


def eval_ppl(api: ModelAPI, params, batches, qcfg: QuantConfig,
             cushion=None, scales=None) -> float:
    """Perplexity over an eval set (paper Tables 1/4 metric)."""
    fn = jax.jit(lambda p, b: api.loss_fn(
        p, b, qcfg, cushion=cushion, scales=scales, remat=False)[1]["ce"])
    tot, n = 0.0, 0
    for b in batches:
        tot += float(fn(params, b))
        n += 1
    return float(np.exp(tot / max(n, 1)))


def eval_next_token_acc(api: ModelAPI, params, batches, qcfg: QuantConfig,
                        cushion=None, scales=None) -> float:
    """Next-token top-1 accuracy — the zero-shot-accuracy stand-in for
    Table 2 at CPU scale."""
    @jax.jit
    def fn(p, b):
        logits, _ = api.forward(p, b, qcfg, cushion=cushion, scales=scales,
                                remat=False)
        # pipeline labels are pre-shifted: labels[:, i] = tokens[:, i+1]
        pred = jnp.argmax(logits, axis=-1)
        return jnp.mean((pred == b["labels"]).astype(jnp.float32))
    vals = [float(fn(params, b)) for b in batches]
    return float(np.mean(vals))
