"""repro.train"""
