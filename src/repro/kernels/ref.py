"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def w8a8_matmul_ref(x_int: jax.Array, w_int: jax.Array, s_x: jax.Array,
                    z_x: jax.Array, s_w: jax.Array) -> jax.Array:
    """(X_int - z_x) @ W_int * s_x*s_w  in fp32. x_int: (M,K) int8,
    w_int: (K,N) int8, scalars fp32."""
    acc = jax.lax.dot_general(
        x_int, w_int, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32).astype(jnp.float32)
    colsum = jnp.sum(w_int.astype(jnp.int32), axis=0).astype(jnp.float32)
    acc = acc - z_x * colsum[None, :]
    return acc * (s_x * s_w)


def act_quant_ref(x: jax.Array, bits: int = 8, per_token: bool = False):
    """Asymmetric quantize; returns (x_int8, scale, zero). Static path takes
    precomputed scale/zero via act_quant_static_ref."""
    qmax = 2 ** bits - 1
    if per_token:
        mn = jnp.min(x, axis=-1, keepdims=True)
        mx = jnp.max(x, axis=-1, keepdims=True)
    else:
        mn = jnp.min(x)
        mx = jnp.max(x)
    mn = jnp.minimum(mn, 0.0)
    mx = jnp.maximum(mx, 0.0)
    scale = jnp.maximum((mx - mn) / qmax, 1e-8)
    zero = jnp.round(jnp.clip(-mn / scale, 0, qmax))
    xq = jnp.clip(jnp.round(x / scale + zero), 0, qmax) - 128
    return xq.astype(jnp.int8), scale, zero


def act_quant_static_ref(x: jax.Array, scale: jax.Array, zero: jax.Array,
                         bits: int = 8) -> jax.Array:
    qmax = 2 ** bits - 1
    xq = jnp.clip(jnp.round(x / scale + zero), 0, qmax) - 128
    return xq.astype(jnp.int8)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, prefix_len: int = 0
                        ) -> jax.Array:
    """q: (B,H,S,hd); k/v: (B,H,T,hd); T = prefix_len + S when causal.
    Prefix positions fully visible (the CushionCache block)."""
    B, H, S, hd = q.shape
    T = k.shape[2]
    logits = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(hd)
    if causal:
        i = jnp.arange(S)[:, None]
        j = jnp.arange(T)[None, :]
        mask = (j < prefix_len) | (j <= i + prefix_len)
        logits = jnp.where(mask[None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", w,
                      v.astype(jnp.float32)).astype(q.dtype)
