"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def w8a8_matmul_ref(x_int: jax.Array, w_int: jax.Array, s_x: jax.Array,
                    z_x: jax.Array, s_w: jax.Array) -> jax.Array:
    """(X_int - z_x) @ W_int * s_x*s_w  in fp32. x_int: (M,K) int8,
    w_int: (K,N) int8, scalars fp32."""
    acc = jax.lax.dot_general(
        x_int, w_int, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32).astype(jnp.float32)
    colsum = jnp.sum(w_int.astype(jnp.int32), axis=0).astype(jnp.float32)
    acc = acc - z_x * colsum[None, :]
    return acc * (s_x * s_w)


def w4a8_matmul_ref(x_int: jax.Array, w_packed: jax.Array, s_x: jax.Array,
                    z_x: jax.Array, s_w: jax.Array,
                    group_size: int) -> jax.Array:
    """Oracle for the int4-packed kernel: dense unpack, per-group int32
    products, f32 scale combine. x_int: (M,K) int8; w_packed: (K//2,N) int8
    nibble pairs (core.quantization.pack_int4 layout); s_x/z_x scalar;
    s_w: (K//group_size, N) group scales. Returns fp32
    (M,N) = s_x * sum_g s_w[g] * (x[:,g] - z_x) @ w[g]."""
    from repro.core.quantization import unpack_int4
    M, K = x_int.shape
    N = w_packed.shape[1]
    G = K // group_size
    w_int = unpack_int4(w_packed, K)                       # (K, N) int8
    xg = x_int.reshape(M, G, group_size)
    wg = w_int.reshape(G, group_size, N)
    parts = jax.lax.dot_general(
        xg, wg, (((2,), (1,)), ((1,), (0,))),              # (G, M, N)
        preferred_element_type=jnp.int32).astype(jnp.float32)
    colsum_g = jnp.sum(wg.astype(jnp.int32), axis=1)       # (G, N)
    parts = parts - z_x * colsum_g[:, None, :].astype(jnp.float32)
    return s_x * jnp.einsum("gmn,gn->mn", parts, s_w.astype(jnp.float32))


def act_quant_ref(x: jax.Array, bits: int = 8, per_token: bool = False):
    """Asymmetric quantize; returns (x_int8, scale, zero). Static path takes
    precomputed scale/zero via act_quant_static_ref."""
    qmax = 2 ** bits - 1
    if per_token:
        mn = jnp.min(x, axis=-1, keepdims=True)
        mx = jnp.max(x, axis=-1, keepdims=True)
    else:
        mn = jnp.min(x)
        mx = jnp.max(x)
    mn = jnp.minimum(mn, 0.0)
    mx = jnp.maximum(mx, 0.0)
    scale = jnp.maximum((mx - mn) / qmax, 1e-8)
    zero = jnp.round(jnp.clip(-mn / scale, 0, qmax))
    xq = jnp.clip(jnp.round(x / scale + zero), 0, qmax) - 128
    return xq.astype(jnp.int8), scale, zero


def act_quant_static_ref(x: jax.Array, scale: jax.Array, zero: jax.Array,
                         bits: int = 8) -> jax.Array:
    qmax = 2 ** bits - 1
    xq = jnp.clip(jnp.round(x / scale + zero), 0, qmax) - 128
    return xq.astype(jnp.int8)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, prefix_len: int = 0
                        ) -> jax.Array:
    """q: (B,H,S,hd); k/v: (B,Kh,T,hd) with Kh | H (GQA); T = prefix_len + S
    when causal. Prefix positions fully visible (the CushionCache block)."""
    B, H, S, hd = q.shape
    Kh, T = k.shape[1], k.shape[2]
    if Kh != H:
        k = jnp.repeat(k, H // Kh, axis=1)
        v = jnp.repeat(v, H // Kh, axis=1)
    logits = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(hd)
    if causal:
        i = jnp.arange(S)[:, None]
        j = jnp.arange(T)[None, :]
        mask = (j < prefix_len) | (j <= i + prefix_len)
        logits = jnp.where(mask[None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", w,
                      v.astype(jnp.float32)).astype(q.dtype)


def flash_decode_ref(q: jax.Array, k: jax.Array, v: jax.Array, pos,
                     k_scale: jax.Array | None = None,
                     v_scale: jax.Array | None = None,
                     kc: jax.Array | None = None,
                     vc: jax.Array | None = None) -> jax.Array:
    """Oracle for the split-KV decode kernel (also the CPU/jnp decode path
    for quantized caches).

    q: (B,H,hd); k/v: (B,Smax,K,hd) — fp, or int8 with per-head dequant
    scales k_scale/v_scale (K,), or per-row (B,K) slot scales (continuous
    batching: each slot's scales come from its own admission prefill).
    kc/vc: (m,K,hd) fp cushion block covering
    absolute positions [0:m) (int8 caches keep the sink block intact; the
    block is visible to every row regardless of pos — the sink is never
    evicted). pos: () or (B,) — row b attends positions [0:pos[b]] (plus
    the cushion block when present). pos[b] < 0 marks a retired row: with
    no cushion it attends nothing and outputs zeros. Returns (B,H,hd) in
    q.dtype.
    """
    B, H, hd = q.shape
    Smax, K = k.shape[1], k.shape[2]
    G = H // K
    m = 0 if kc is None else kc.shape[0]
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if k_scale is not None:
        ks = k_scale.astype(jnp.float32)
        vs = v_scale.astype(jnp.float32)
        if ks.ndim == 2:                       # per-row (B, K)
            kf = kf * ks[:, None, :, None]
            vf = vf * vs[:, None, :, None]
        else:
            kf = kf * ks[None, None, :, None]
            vf = vf * vs[None, None, :, None]
    if m:
        kcb = jnp.broadcast_to(kc.astype(jnp.float32)[None], (B,) + kc.shape)
        vcb = jnp.broadcast_to(vc.astype(jnp.float32)[None], (B,) + vc.shape)
        kf = jnp.concatenate([kcb, kf[:, m:]], axis=1)
        vf = jnp.concatenate([vcb, vf[:, m:]], axis=1)
    qg = q.reshape(B, K, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,btkh->bkgt", qg, kf) / np.sqrt(hd)
    posv = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))
    idx = jnp.arange(Smax)
    valid = idx[None, :] <= posv[:, None]              # (B, Smax)
    if m:
        valid = valid | (idx < m)[None, :]             # cushion never masked
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkh->bkgh", w, vf)
    # fully-masked rows (retired, no cushion): zeros, not a uniform average
    out = jnp.where(jnp.any(valid, axis=1)[:, None, None, None], out, 0.0)
    return out.reshape(B, H, hd).astype(q.dtype)


def gather_pages(pages: jax.Array, page_table: jax.Array) -> jax.Array:
    """Materialize a paged KV pool as the dense per-row layout:
    pages (n_pages, ps, K, hd) + page_table (B, P) -> (B, P*ps, K, hd).
    Row b's positions [j*ps, (j+1)*ps) come from physical page
    page_table[b, j]; unmapped entries read the scratch page 0, whose
    content is masked by pos / the cushion boundary downstream."""
    B, P = page_table.shape
    ps = pages.shape[1]
    g = pages[page_table]                       # (B, P, ps, K, hd)
    return g.reshape(B, P * ps, *pages.shape[2:])


def flash_decode_paged_ref(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, page_table: jax.Array, pos,
                           k_scale: jax.Array | None = None,
                           v_scale: jax.Array | None = None,
                           kc: jax.Array | None = None,
                           vc: jax.Array | None = None) -> jax.Array:
    """Oracle for ``flash_decode_paged``: gather the page table into the
    dense layout, then score with ``flash_decode_ref`` (the paging oracle —
    paged attention IS dense attention over the gathered cache). fp pools
    may carry a cushion block here (see flash_decode_paged)."""
    return flash_decode_ref(q, gather_pages(k_pages, page_table),
                            gather_pages(v_pages, page_table), pos,
                            k_scale=k_scale, v_scale=v_scale, kc=kc, vc=vc)
