"""jit'd wrappers routing model-level ops through the Pallas kernels
(TPU execution path; `interpret=True` everywhere on CPU for validation).

`qdot_pallas` is the drop-in for core.quantization.true_int_dot when
ParallelConfig.use_pallas is set: fused act-quant kernel -> int8 MXU matmul
kernel with the static-scale epilogue. `attention_pallas` replaces the jnp
flash path (it expects GQA-expanded heads).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import QuantConfig
from repro.core import quantization as Q
from repro.kernels.act_quant import act_quant_ptoken, act_quant_static
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import flash_decode, flash_decode_paged
from repro.kernels.w8a8_matmul import w8a8_matmul


def _pad_to(x: jax.Array, mult: int, axis: int) -> Tuple[jax.Array, int]:
    n = x.shape[axis]
    target = -(-n // mult) * mult
    if target == n:
        return x, n
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - n)
    return jnp.pad(x, pad), n


def qdot_pallas(x: jax.Array, w: jax.Array, cfg: QuantConfig,
                site: Optional[Q.SiteScale] = None,
                interpret: bool = True) -> jax.Array:
    """x: (..., K) fp; w: (K, N) fp. Full W8A8 per-tensor-static path on the
    Pallas kernels: quantize activations (fused kernel), quantize weights
    (host-side constant fold under jit), int8 matmul with scalar epilogue.

    The int8 storage is offset by -128 in act_quant; the equivalent
    zero-point seen by the matmul is z - 128.
    """
    assert cfg.mode == "pt_static" and site is not None
    orig_shape = x.shape
    M = 1
    for d in orig_shape[:-1]:
        M *= d
    K = orig_shape[-1]
    x2 = x.reshape(M, K)
    x2, M0 = _pad_to(x2, 128, 0)

    wq, s_w = Q.weight_quant_int(w, cfg)
    xq = act_quant_static(x2, site.scale, site.zero, bits=cfg.a_bits,
                          bm=min(128, x2.shape[0]), interpret=interpret)
    out = w8a8_matmul(xq, wq, site.scale, site.zero - 128.0, s_w,
                      bm=min(128, xq.shape[0]), bn=min(512, w.shape[1]),
                      bk=min(256, K), interpret=interpret)
    out = out[:M0].reshape(*orig_shape[:-1], w.shape[1])
    return out.astype(x.dtype)


def attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                     causal: bool = True, prefix_len: int = 0,
                     interpret: bool = True) -> jax.Array:
    """q: (B,S,H,hd); k/v: (B,T,Kh,hd). GQA kv-heads are indexed natively
    inside the flash kernel's BlockSpec index maps — no G× head expansion is
    ever materialized in HBM. Returns (B,S,H,hd)."""
    B, S, H, hd = q.shape
    T = k.shape[1]
    qh = jnp.transpose(q, (0, 2, 1, 3))
    kh = jnp.transpose(k, (0, 2, 1, 3))
    vh = jnp.transpose(v, (0, 2, 1, 3))
    o = flash_attention(qh, kh, vh, causal=causal, prefix_len=prefix_len,
                        bq=min(256, S), bkv=min(512, T),
                        interpret=interpret)
    return jnp.transpose(o, (0, 2, 1, 3))


def decode_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, pos,
                            k_scale: jax.Array | None = None,
                            v_scale: jax.Array | None = None,
                            kc: jax.Array | None = None,
                            vc: jax.Array | None = None,
                            interpret: bool = False) -> jax.Array:
    """Model-level entry for the split-KV decode kernel. q: (B,H,hd);
    k/v: the (B,Smax,K,hd) cache (int8 when scales given, cushion block in
    kc/vc); pos: () shared or (B,) per-row decode positions (continuous
    batching — rows with pos < 0 are retired/compute-masked). Returns
    (B,H,hd)."""
    return flash_decode(q, k, v, pos, k_scale=k_scale, v_scale=v_scale,
                        kc=kc, vc=vc, interpret=interpret)


def decode_attention_tp(q: jax.Array, k: jax.Array, v: jax.Array, pos,
                        mesh, axis: str = "tp",
                        k_scale: jax.Array | None = None,
                        v_scale: jax.Array | None = None,
                        kc: jax.Array | None = None,
                        vc: jax.Array | None = None,
                        interpret: bool = False) -> jax.Array:
    """Tensor-parallel split-KV decode: ``shard_map`` the flash-decode
    kernel over the mesh's ``axis`` with per-shard head slicing.

    Sharding contract (requires K % tp == 0; callers fall back to the
    unsharded entry otherwise):
      q        (B, H, hd)      heads axis sharded — H = K*G splits on KV-head
                               boundaries, so each shard's G-groups stay
                               aligned with its local KV heads
      k/v      (B, Smax, K, hd) KV-heads axis sharded (the serve-pool layout
                               from models/*.cache_roles)
      k/v_scale (K,) or (B,K)  sharded with the heads they dequantize
                               (per-slot scales keep batch replicated)
      kc/vc    (m, K, hd)      stored replicated (cushion bit-identity per
                               shard); sliced to the local heads on entry
      pos      () or (B,)      replicated

    Each shard runs the whole split-KV kernel on its local heads — per-head
    attention is embarrassingly parallel, so the body needs no collectives;
    the surrounding o-projection (wo sharded ("M", None)) contributes the
    one psum per layer. Returns q-sharded (B, H, hd)."""
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import shard_map_compat

    quantized = k_scale is not None
    pos_spec = P() if jnp.ndim(pos) == 0 else P(None)
    hs = P(None, axis, None)             # (B, H, hd) heads-sharded
    kvs = P(None, None, axis, None)      # (B, Smax, K, hd) kv-heads-sharded
    if quantized:
        sspec = P(None, axis) if jnp.ndim(k_scale) == 2 else P(axis)
        def body(q, k, v, pos, ksc, vsc, kc, vc):
            return flash_decode(q, k, v, pos, k_scale=ksc, v_scale=vsc,
                                kc=kc, vc=vc, interpret=interpret)
        f = shard_map_compat(
            body, mesh,
            in_specs=(hs, kvs, kvs, pos_spec, sspec, sspec,
                      P(None, axis, None), P(None, axis, None)),
            out_specs=hs)
        return f(q, k, v, pos, k_scale, v_scale, kc, vc)

    def body(q, k, v, pos):
        return flash_decode(q, k, v, pos, interpret=interpret)
    f = shard_map_compat(body, mesh, in_specs=(hs, kvs, kvs, pos_spec),
                         out_specs=hs)
    return f(q, k, v, pos)


def decode_attention_paged(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, page_table: jax.Array, pos,
                           k_scale: jax.Array | None = None,
                           v_scale: jax.Array | None = None,
                           kc: jax.Array | None = None,
                           vc: jax.Array | None = None,
                           interpret: bool = False) -> jax.Array:
    """Model-level entry for the paged split-KV decode kernel. q: (B,H,hd);
    k/v_pages: (n_pages, ps, K, hd) page store (int8 when scales given);
    page_table: (B, P) int32 slot page tables (scalar-prefetched into the
    kernel's index maps); pos: () or (B,) logical decode positions; kc/vc:
    the shared batch-free cushion block (fp AND int8 pools — paging stores
    the cushion once, outside the pages). Returns (B,H,hd)."""
    return flash_decode_paged(q, k_pages, v_pages, page_table, pos,
                              k_scale=k_scale, v_scale=v_scale,
                              kc=kc, vc=vc, interpret=interpret)


def decode_attention_tp_paged(q: jax.Array, k_pages: jax.Array,
                              v_pages: jax.Array, page_table: jax.Array,
                              pos, mesh, axis: str = "tp",
                              k_scale: jax.Array | None = None,
                              v_scale: jax.Array | None = None,
                              kc: jax.Array | None = None,
                              vc: jax.Array | None = None,
                              interpret: bool = False) -> jax.Array:
    """Tensor-parallel paged decode: ``shard_map`` ``flash_decode_paged``
    over ``axis`` with per-shard head slicing, exactly as
    ``decode_attention_tp`` — the page store shards its K axis
    ((n_pages, ps, K, hd), serving pool roles), the page table is
    replicated (page ids are layout metadata, identical per shard), and the
    shared cushion block is replicated and sliced to local heads on entry.
    Requires K % tp == 0."""
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import shard_map_compat

    quantized = k_scale is not None
    pos_spec = P() if jnp.ndim(pos) == 0 else P(None)
    hs = P(None, axis, None)              # (B, H, hd) heads-sharded
    pgs = P(None, None, axis, None)       # (n_pages, ps, K, hd)
    pts = P(None, None)                   # (B, P) replicated
    cus = P(None, axis, None)             # (m, K, hd) sliced per shard
    if quantized:
        sspec = P(None, axis) if jnp.ndim(k_scale) == 2 else P(axis)
        def body(q, k, v, pt, pos, ksc, vsc, kc, vc):
            return flash_decode_paged(q, k, v, pt, pos, k_scale=ksc,
                                      v_scale=vsc, kc=kc, vc=vc,
                                      interpret=interpret)
        f = shard_map_compat(
            body, mesh,
            in_specs=(hs, pgs, pgs, pts, pos_spec, sspec, sspec, cus, cus),
            out_specs=hs)
        return f(q, k_pages, v_pages, page_table, pos, k_scale, v_scale,
                 kc, vc)
    if kc is not None:
        def body(q, k, v, pt, pos, kc, vc):
            return flash_decode_paged(q, k, v, pt, pos, kc=kc, vc=vc,
                                      interpret=interpret)
        f = shard_map_compat(
            body, mesh,
            in_specs=(hs, pgs, pgs, pts, pos_spec, cus, cus), out_specs=hs)
        return f(q, k_pages, v_pages, page_table, pos, kc, vc)

    def body(q, k, v, pt, pos):
        return flash_decode_paged(q, k, v, pt, pos, interpret=interpret)
    f = shard_map_compat(body, mesh,
                         in_specs=(hs, pgs, pgs, pts, pos_spec),
                         out_specs=hs)
    return f(q, k_pages, v_pages, page_table, pos)
