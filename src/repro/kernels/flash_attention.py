"""Pallas TPU kernel: flash attention with a CushionCache prefix block.

Online-softmax tiling: grid = (batch*heads, S/bq); each program streams KV
tiles HBM->VMEM, keeping the probability tile entirely in VMEM — this is the
fix for the dominant HBM term the dry-run roofline exposes in the pure-jnp
path (attention-probability materialization).

Cushion prefix: keys/values are laid out [prefix | content]; a query at
content position i may attend every j < prefix_len (the sink block — NO
causal masking against the prefix, paper §4/eq. 8) plus content positions
j <= i. Masking is computed from absolute tile indices, so the prefix block
costs one extra KV tile, not a second kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bkv: int, n_kv: int, prefix_len: int, causal: bool,
            scale: float, T: int):
    kv_i = pl.program_id(2)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)            # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)         # (bkv, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qi = pl.program_id(1) * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bkv), 0)
    kj = kv_i * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    valid = kj < T
    if causal:
        valid &= (kj < prefix_len) | (kj <= qi + prefix_len)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(valid, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kv_i == n_kv - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "prefix_len", "bq",
                                             "bkv", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, prefix_len: int = 0,
                    bq: int = 256, bkv: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q: (B,H,S,hd); k/v: (B,Kh,T,hd) with Kh | H. Returns (B,H,S,hd).

    GQA is native: the kv-head for grid row b is picked in the k/v BlockSpec
    index maps ((b % H) // G), so grouped caches are streamed HBM->VMEM at
    their stored Kh-head size — never expanded G× in HBM.

    VMEM working set: q/k/v/p tiles + fp32 accumulator
      bq*hd + 2*bkv*hd + bq*bkv + bq*hd(fp32) ≈ 1.1 MB at (256, 512, 128).
    """
    B, H, S, hd = q.shape
    Kh, T = k.shape[1], k.shape[2]
    G = H // Kh
    bq = min(bq, S)
    bkv = min(bkv, T)
    Sp = -(-S // bq) * bq
    Tp = -(-T // bkv) * bkv
    if Sp != S:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
    if Tp != T:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Tp - T), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Tp - T), (0, 0)))
    n_kv = Tp // bkv
    qf = q.reshape(B * H, Sp, hd)
    scale = 1.0 / np.sqrt(hd)

    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bkv=bkv, n_kv=n_kv,
                          prefix_len=prefix_len, causal=causal, scale=scale,
                          T=T),
        grid=(B * H, Sp // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, 1, bkv, hd),
                         lambda b, i, j: (b // H, (b % H) // G, j, 0)),
            pl.BlockSpec((1, 1, bkv, hd),
                         lambda b, i, j: (b // H, (b % H) // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sp, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, hd), jnp.float32)],
        interpret=interpret,
    )(qf, k, v)
    return out.reshape(B, H, Sp, hd)[:, :, :S]
