"""Pallas TPU kernel: W8A8 per-tensor-static matmul.

int8 x int8 tiles stream HBM->VMEM, accumulate on the MXU in int32, and the
epilogue applies the single fused scalar dequant s_x*s_w plus the asymmetric
zero-point correction  -z_x * colsum(W)  — the whole point of per-tensor
*static* quantization: no per-channel/per-token scale traffic anywhere near
the contracting dimension (DESIGN.md §3), and int8 doubles MXU throughput.

Block shapes default to (256, 512, 256): MXU-aligned (multiples of 128);
VMEM working set = bm*bk + bk*bn + bm*bn*4B ≈ 0.85 MB « 16 MB VMEM, leaving
room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, colsum_ref, scale_ref, zx_ref, o_ref, acc_ref, *,
            n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _epilogue():
        acc = acc_ref[...].astype(jnp.float32)
        # zero-point correction: (X - z)W = XW - z * colsum(W)
        acc = acc - zx_ref[0] * colsum_ref[...][None, :].astype(jnp.float32)
        o_ref[...] = acc * scale_ref[0]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def w8a8_matmul(x_int: jax.Array, w_int: jax.Array, s_x, z_x, s_w,
                colsum: jax.Array | None = None,
                bm: int = 256, bn: int = 512, bk: int = 256,
                interpret: bool = False) -> jax.Array:
    """x_int: (M,K) int8; w_int: (K,N) int8; s_x/z_x/s_w scalar fp32.
    Returns fp32 (M,N) = (x - z_x) @ w * s_x * s_w.

    M may be ragged (serving token counts): the grid tiles M with a fixed
    block and the LAST tile is a partial boundary block — Pallas masks its
    out-of-bounds store rows and pads its out-of-bounds load rows, whose
    garbage never lands anywhere. No pad-to-max copy of the activations is
    ever materialized (the old path zero-padded (M,K) up to the tile in
    HBM, which at prefill sizes cost more than the matmul it fed). K/N are
    weight dimensions — static per checkpoint — and must tile exactly.

    colsum: optional precomputed (N,) int32 column sums of ``w_int`` — the
    prequantized serving path stores them with the int8 weights so the
    zero-point correction never re-reduces the weight per call."""
    M, K = x_int.shape
    K2, N = w_int.shape
    assert K == K2
    bn, bk = min(bn, N), min(bk, K)
    assert N % bn == 0 and K % bk == 0, \
        f"weight dims ({K},{N}) must tile by ({bk},{bn})"
    # fixed M tile, sublane-aligned (int8 min tile is (32, 128)): small M
    # (decode) gets one snug block, large M (prefill) a grid of full tiles
    # plus one masked boundary block
    bm = min(bm, -(-M // 32) * 32)
    n_k = K // bk
    if colsum is None:
        colsum = jnp.sum(w_int.astype(jnp.int32), axis=0)   # (N,), tiny
    colsum = colsum.astype(jnp.int32)
    scale = (jnp.asarray(s_x, jnp.float32)
             * jnp.asarray(s_w, jnp.float32)).reshape(1)
    zx = jnp.asarray(z_x, jnp.float32).reshape(1)

    grid = (-(-M // bm), N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
            pl.BlockSpec((1,), lambda i, j, k: (0,)),
            pl.BlockSpec((1,), lambda i, j, k: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_int, w_int, colsum, scale, zx)
