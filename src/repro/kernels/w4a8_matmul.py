"""Pallas TPU kernel: W4A8 matmul — int4-packed weights unpacked in VMEM.

Weights stream HBM->VMEM as nibble-packed int8 (0.5 byte/weight — half the
W8A8 traffic, the whole point at bandwidth-bound decode), are sign-extended
to int8 values *in VMEM* (two arithmetic shifts + an interleave, VPU work
that overlaps the MXU), and feed the same int8 MXU product as ``w8a8_matmul``.
Weight scales are group-wise along the contracting dim: each k-block sits
inside exactly one group (``bk`` must divide ``group_size``), so the block's
int32 partial product is scaled by one (1, bn) scale row and accumulated in
an f32 VMEM scratch. The epilogue applies the activation scale and the
asymmetric zero-point correction  -z_x * colsum  where ``colsum`` is the
*scale-weighted* column sum  sum_g s_w[g,n] * colsum_g[n]  precomputed at
prequantize time — group scales never touch the epilogue's rank-1 subtract.

Packing layout (``core.quantization.pack_int4``): byte i of a packed column
holds element 2i in its low nibble and 2i+1 in its high nibble, so unpacking
is stack([lo, hi], axis=1).reshape — a sublane-dim interleave, no lane
shuffles. The ragged-M grid is inherited from ``w8a8_matmul`` (PR 8): fixed
sublane-aligned M tile, masked boundary block, no pad-to-max copy.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, wp_ref, scale_ref, colsum_ref, zx_ref, o_ref, acc_ref, *,
            n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # unpack the (bk//2, bn) nibble block to (bk, bn) int32 in VMEM:
    # low nibble sign-extends from bit 3, high nibble is the arithmetic
    # floor-division of the two's-complement byte
    p = wp_ref[...].astype(jnp.int32)
    lo = (p << 28) >> 28
    hi = p >> 4
    w_blk = jnp.stack([lo, hi], axis=1).reshape(p.shape[0] * 2, p.shape[1])
    blk = jax.lax.dot_general(
        x_ref[...], w_blk.astype(jnp.int8), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)    # int8 x int8 on the MXU
    # one group scale row per k-block (bk divides group_size)
    acc_ref[...] += blk.astype(jnp.float32) * scale_ref[...]

    @pl.when(pl.program_id(2) == n_k - 1)
    def _epilogue():
        # zero-point correction: (X - z)W = XW - z * colsum(W); colsum
        # already carries the group scales, so only s_x remains
        acc = acc_ref[...] - zx_ref[0] * colsum_ref[...][None, :]
        o_ref[...] = acc * zx_ref[1]


@functools.partial(jax.jit, static_argnames=("group_size", "bm", "bn", "bk",
                                             "interpret"))
def w4a8_matmul(x_int: jax.Array, w_packed: jax.Array, s_x, z_x, s_w,
                colsum: jax.Array, group_size: int,
                bm: int = 256, bn: int = 512, bk: int = 256,
                interpret: bool = False) -> jax.Array:
    """x_int: (M,K) int8; w_packed: (K//2,N) int8 nibble pairs; s_x/z_x
    scalar fp32; s_w: (K//group_size, N) fp32 group scales; colsum: (N,)
    fp32 scale-weighted column sums. Returns fp32
    (M,N) = s_x * (sum_g s_w[g] * (x[:,g] - z_x) @ w[g]).

    M may be ragged (serving token counts): fixed sublane-aligned M tile,
    partial boundary block masked by Pallas — same grid as ``w8a8_matmul``.
    K and N are weight dims, static per checkpoint: K must be even and
    groups must tile it; ``bk`` is clamped to a power-of-two block that
    divides ``group_size`` so every k-block reads exactly one scale row.
    """
    M, K = x_int.shape
    Kp, N = w_packed.shape
    assert K % 2 == 0 and Kp * 2 == K, \
        f"packed contracting dim mismatch: K={K}, packed rows={Kp}"
    G = s_w.shape[0]
    assert G * group_size == K, \
        f"groups ({G} x {group_size}) must tile the contracting dim ({K})"
    bn = min(bn, N)
    while N % bn:
        bn //= 2
    # largest power-of-two k-block <= bk that divides the group (so the
    # scale row is constant per block) and keeps the packed rows even
    bk = min(bk, group_size)
    while group_size % bk or bk % 2:
        bk //= 2
    assert bk >= 2, f"group_size ({group_size}) must be even"
    bm = min(bm, -(-M // 32) * 32)
    n_k = K // bk
    spg = group_size // bk                       # k-blocks per scale row
    scale = jnp.asarray(s_w, jnp.float32)
    zx = jnp.stack([jnp.asarray(z_x, jnp.float32).reshape(()),
                    jnp.asarray(s_x, jnp.float32).reshape(())])

    grid = (-(-M // bm), N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (k // spg, j)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
            pl.BlockSpec((2,), lambda i, j, k: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x_int, w_packed, scale, colsum.astype(jnp.float32), zx)
