"""Pallas TPU kernel: fused asymmetric activation quantization.

Static path: one pass — x/s + z, round, clip, emit int8 (the per-tensor
static deployment path; scale/zero are calibration constants, so the kernel
is purely elementwise and fuses into the matmul pipeline's producer side).

Per-token path: row-wise min/max reduction and quantize in one VMEM pass —
a row fits comfortably in VMEM for every assigned d_model (≤ 8192 fp32 =
32 KB/row).

Output int8 is offset by -128 (symmetric storage) so the downstream int8
MXU matmul consumes it directly; the matching zero-point shift is folded
into the correction term by the caller (ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _static_kernel(x_ref, s_ref, z_ref, o_ref, *, qmax: int):
    x = x_ref[...].astype(jnp.float32)
    q = jnp.clip(jnp.round(x / s_ref[0] + z_ref[0]), 0, qmax) - 128
    o_ref[...] = q.astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("bits", "bm", "interpret"))
def act_quant_static(x: jax.Array, scale, zero, bits: int = 8,
                     bm: int = 256, interpret: bool = False) -> jax.Array:
    """x: (M, D) -> int8 (M, D) with precomputed per-tensor scale/zero."""
    M, D = x.shape
    bm = min(bm, M)
    assert M % bm == 0
    qmax = 2 ** bits - 1
    s = jnp.asarray(scale, jnp.float32).reshape(1)
    z = jnp.asarray(zero, jnp.float32).reshape(1)
    return pl.pallas_call(
        functools.partial(_static_kernel, qmax=qmax),
        grid=(M // bm,),
        in_specs=[pl.BlockSpec((bm, D), lambda i: (i, 0)),
                  pl.BlockSpec((1,), lambda i: (0,)),
                  pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=pl.BlockSpec((bm, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, D), jnp.int8),
        interpret=interpret,
    )(x, s, z)


def _ptoken_kernel(x_ref, o_ref, s_ref, z_ref, *, qmax: int):
    x = x_ref[...].astype(jnp.float32)
    mn = jnp.minimum(jnp.min(x, axis=-1, keepdims=True), 0.0)
    mx = jnp.maximum(jnp.max(x, axis=-1, keepdims=True), 0.0)
    scale = jnp.maximum((mx - mn) / qmax, 1e-8)
    zero = jnp.round(jnp.clip(-mn / scale, 0, qmax))
    q = jnp.clip(jnp.round(x / scale + zero), 0, qmax) - 128
    o_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale
    z_ref[...] = zero


@functools.partial(jax.jit, static_argnames=("bits", "bm", "interpret"))
def act_quant_ptoken(x: jax.Array, bits: int = 8, bm: int = 256,
                     interpret: bool = False):
    """x: (M, D) -> (int8 (M,D), scale (M,1), zero (M,1)) per-token."""
    M, D = x.shape
    bm = min(bm, M)
    assert M % bm == 0
    qmax = 2 ** bits - 1
    out, s, z = pl.pallas_call(
        functools.partial(_ptoken_kernel, qmax=qmax),
        grid=(M // bm,),
        in_specs=[pl.BlockSpec((bm, D), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bm, D), lambda i: (i, 0)),
                   pl.BlockSpec((bm, 1), lambda i: (i, 0)),
                   pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((M, D), jnp.int8),
                   jax.ShapeDtypeStruct((M, 1), jnp.float32),
                   jax.ShapeDtypeStruct((M, 1), jnp.float32)],
        interpret=interpret,
    )(x)
    return out, s, z
