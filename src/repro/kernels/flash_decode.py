"""Pallas TPU kernel: split-KV (flash-decoding style) single-query attention
for the serving decode hot path.

Decode is HBM-bound: every generated token streams the whole KV cache once,
so the kernel's job is (a) never materialize anything bigger than a KV tile
in VMEM, (b) read the cache at its storage precision (int8 halves the
dominant roofline term), and (c) never expand GQA kv-heads in HBM.

Layout / grid
-------------
q: (B, H, hd) single-token queries, reshaped to (B, K, G, hd) so each grid
program owns one (batch, kv-head) pair and its G query heads. The KV cache
keeps the model's native (B, Smax, K, hd) layout; the kv-head is selected in
the BlockSpec index maps (``b % K``) — GQA needs no `jnp.repeat`, no head
materialization, no transpose of the cache. Grid = (B*K, Smax/bkv) with the
KV-chunk axis innermost and sequential: online-softmax partial (max, sum,
acc) statistics live in VMEM scratch and are combined across chunks exactly
like flash-decoding's split-KV reduction.

Masking comes from the live ``pos`` value — a scalar shared by the batch or
a per-row ``(B,)`` vector (the continuous-batching scheduler gives every
cache slot its own decode position): chunks entirely beyond the row's
``pos`` skip their compute via ``pl.when`` (their DMA still happens — the
price of static shapes), and the tail chunk is masked per-position. A row
with ``pos < 0`` is *retired*: it attends to nothing (fp mode -> zeros) or
to the always-visible cushion block only (int8+cushion mode). The
continuous-batching scheduler compute-masks dead slots by *freezing* their
pos (a negative pos would make the slot's cache write clamp onto the
cushion rows); pos < 0 is the kernel-level contract for callers that
never write, and the jnp fallback/oracle honor the same semantics.

int8-KV variant
---------------
When per-(layer,head) scales are provided, k/v refs are int8 and are
dequantized in-kernel (one scalar multiply per tile, fused on the VPU).
The cushion/sink prefix block [0:m) is NOT quantized: following
KVSink/IntactKV, sink-token KV must stay intact or the whole softmax
distribution degrades. It is read from a separate full-precision ref
(``kc``/``vc``, batch-free — the cushion is shared across the batch) and
folded into the online softmax as the first block; the int8 cache holds
content positions only, and positions below the cushion length are masked
out of the int8 read.

Paged variant
-------------
``flash_decode_paged`` reads the same online-softmax body through a page
table instead of dense per-row caches: the KV store is a flat page pool
``(n_pages, page_size, K, hd)`` and each batch row owns a ``(P,)`` row of
the scalar-prefetched ``page_table`` mapping logical page ``j`` (cache
positions ``[j*ps, (j+1)*ps)``) to a physical page. The only change is the
k/v BlockSpec index map — ``(b // K, j, ...)`` becomes
``(page_table[b // K, j], 0, ...)`` — the grid, masking arithmetic (``kj``
stays the *logical* position) and scratch reduction are untouched, so a
page table that happens to be the identity reproduces the contiguous
kernel bit-for-bit at matched chunk size. Unmapped logical pages point at
the reserved scratch page 0; their positions are always masked (beyond
``pos`` or below the cushion), so scratch content is don't-care. Unlike
the contiguous entry, fp pools may pass a cushion block here: paging moves
the cushion out of the per-slot rows into one shared batch-free ref for
every dtype (serving/paging.py).

Tensor parallelism
------------------
The kernel is head-parallel by construction (the grid never mixes kv
heads), so a tp mesh shards it by slicing heads per device —
``kernels/ops.py:decode_attention_tp`` shard_maps this entry over the
``tp`` axis with q/KV/scales sliced along their heads axes and the
replicated fp cushion block sliced to local heads on entry (the stored
block stays whole on every shard; see models/*.cache_roles). Requires
K % tp == 0; model code falls back to the unsharded entry otherwise.
``decode_attention_tp_paged`` does the same for the paged entry with the
page table replicated (page ids are shard-local row metadata, identical
on every shard).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(*refs, bkv: int, n_kv: int, cushion_m: int, mp: int,
            quantized: bool, scale: float):
    pos_ref, q_ref, k_ref, v_ref = refs[:4]
    i = 4
    if quantized:
        ks_ref, vs_ref = refs[i], refs[i + 1]
        i += 2
    if cushion_m:
        kc_ref, vc_ref = refs[i], refs[i + 1]
        i += 2
    o_ref = refs[i]
    m_ref, l_ref, acc_ref = refs[i + 1:i + 4]

    j = pl.program_id(1)
    pos = pos_ref[0]
    q = q_ref[0, 0].astype(jnp.float32)                  # (Gp, hd)
    Gp = q.shape[0]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if cushion_m:
        # Fold the protected fp cushion block [0:m) once, as the first
        # online-softmax block (every decode query sees the full sink block).
        @pl.when(j == 0)
        def _cushion():
            kc = kc_ref[:, 0].astype(jnp.float32)        # (mp, hd)
            vc = vc_ref[:, 0].astype(jnp.float32)
            s = jax.lax.dot_general(q, kc, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32) * scale
            jc = jax.lax.broadcasted_iota(jnp.int32, (Gp, mp), 1)
            valid = jc < cushion_m
            s = jnp.where(valid, s, NEG_INF)
            m0 = jnp.max(s, axis=-1, keepdims=True)
            p = jnp.where(valid, jnp.exp(s - m0), 0.0)
            l_ref[...] = jnp.sum(p, axis=-1, keepdims=True)
            acc_ref[...] = jax.lax.dot_general(
                p, vc, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            m_ref[...] = m0

    @pl.when(j * bkv <= pos)       # chunks fully beyond pos: skip compute
    def _chunk():
        k = k_ref[0, :, 0].astype(jnp.float32)           # (bkv, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)
        if quantized:
            k = k * ks_ref[0]
            v = v * vs_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kj = j * bkv + jax.lax.broadcasted_iota(jnp.int32, (Gp, bkv), 1)
        valid = kj <= pos
        if cushion_m:
            valid &= kj >= cushion_m      # [0:m) lives in the fp cushion ref
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_kv - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bkv", "interpret"))
def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array, pos,
                 k_scale: jax.Array | None = None,
                 v_scale: jax.Array | None = None,
                 kc: jax.Array | None = None,
                 vc: jax.Array | None = None,
                 bkv: int = 512, interpret: bool = False) -> jax.Array:
    """Single-token decode attention over a (possibly int8) KV cache.

    q: (B, H, hd) — the one new query per sequence.
    k/v: (B, Smax, K, hd) cache in storage layout; fp, or int8 when
        k_scale/v_scale are given — (K,) fp32 per-head dequant scales
        shared by the batch, or per-row (B, K) scales (the continuous
        pool calibrates each slot's scales at its own admission prefill;
        the index map then routes row b's scales to its programs).
    pos: () or (B,) int32 — absolute position of each row's just-written
        token; only cache positions <= pos[b] are attended by row b. A
        scalar is shared by the whole batch; a vector gives every row its
        own decode position (continuous batching: slots prefilled at
        different times decode in lock-step). pos[b] < 0 marks a retired
        row: it attends nothing (fp) or the cushion block only (int8).
    kc/vc: (m, K, hd) fp cushion prefix block covering absolute positions
        [0:m) (int8 caches only; requires pos >= m for live rows; the block
        stays visible to retired rows). Batch-free — the CushionCache is
        shared across sequences.

    Returns (B, H, hd). VMEM working set per program:
        G*hd (q) + 2*bkv*hd (kv tile) + G*bkv (p) + G*hd fp32 (acc).
    """
    B, H, hd = q.shape
    Smax, K = k.shape[1], k.shape[2]
    G = H // K
    quantized = k_scale is not None
    m = 0 if kc is None else kc.shape[0]
    assert quantized or m == 0, "fp caches hold the cushion in-cache"

    Gp = -(-G // 8) * 8                # sublane-align the query-head block
    q4 = q.reshape(B, K, G, hd)
    if Gp != G:
        q4 = jnp.pad(q4, ((0, 0), (0, 0), (0, Gp - G), (0, 0)))
    bkv = min(bkv, Smax)
    while Smax % bkv and bkv > 8:
        # prefer a chunk size that divides Smax: a ragged tail would force a
        # jnp.pad — a full HBM copy of the cache EVERY decode step (callers
        # size caches to multiples of 128, so this normally stops at a
        # power-of-two >= 128)
        bkv //= 2
    Tp = -(-Smax // bkv) * bkv
    if Tp != Smax:
        pad = ((0, 0), (0, Tp - Smax), (0, 0), (0, 0))
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    n_kv = Tp // bkv
    mp = m
    if m:
        mp = -(-m // 8) * 8
        if mp != m:
            padc = ((0, mp - m), (0, 0), (0, 0))
            kc = jnp.pad(kc, padc)
            vc = jnp.pad(vc, padc)
    # scalar pos -> broadcast; (B,) pos -> one entry per batch row, routed
    # to its (batch, kv-head) programs through the index map below
    posa = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))
    scale = 1.0 / np.sqrt(hd)

    in_specs = [
        pl.BlockSpec((1,), lambda b, j: (b // K,)),                       # pos
        pl.BlockSpec((1, 1, Gp, hd), lambda b, j: (b // K, b % K, 0, 0)), # q
        pl.BlockSpec((1, bkv, 1, hd), lambda b, j: (b // K, j, b % K, 0)),
        pl.BlockSpec((1, bkv, 1, hd), lambda b, j: (b // K, j, b % K, 0)),
    ]
    args = [posa, q4, k, v]
    if quantized:
        if jnp.ndim(k_scale) == 2:      # per-row (B, K) slot scales
            sspec = pl.BlockSpec((1, 1), lambda b, j: (b // K, b % K))
        else:                           # (K,) shared by the batch
            sspec = pl.BlockSpec((1,), lambda b, j: (b % K,))
        in_specs += [sspec, sspec]
        args += [jnp.asarray(k_scale, jnp.float32),
                 jnp.asarray(v_scale, jnp.float32)]
    if m:
        in_specs += [pl.BlockSpec((mp, 1, hd), lambda b, j: (0, b % K, 0)),
                     pl.BlockSpec((mp, 1, hd), lambda b, j: (0, b % K, 0))]
        args += [kc, vc]

    out = pl.pallas_call(
        functools.partial(_kernel, bkv=bkv, n_kv=n_kv, cushion_m=m, mp=mp,
                          quantized=quantized, scale=scale),
        grid=(B * K, n_kv),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, Gp, hd),
                               lambda b, j: (b // K, b % K, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, Gp, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((Gp, 1), jnp.float32),
                        pltpu.VMEM((Gp, 1), jnp.float32),
                        pltpu.VMEM((Gp, hd), jnp.float32)],
        interpret=interpret,
    )(*args)
    return out[:, :, :G].reshape(B, H, hd)


@functools.partial(jax.jit, static_argnames=("interpret",))
def flash_decode_paged(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                       page_table: jax.Array, pos,
                       k_scale: jax.Array | None = None,
                       v_scale: jax.Array | None = None,
                       kc: jax.Array | None = None,
                       vc: jax.Array | None = None,
                       interpret: bool = False) -> jax.Array:
    """Single-token decode attention over a paged (possibly int8) KV pool.

    q: (B, H, hd) — one new query per pool slot.
    k_pages/v_pages: (n_pages, ps, K, hd) flat page store; fp, or int8 when
        k_scale/v_scale are given ((K,) shared or per-row (B, K) scales,
        exactly as in ``flash_decode``).
    page_table: (B, P) int32 — row b's logical page j holds cache positions
        [j*ps, (j+1)*ps) and lives at physical page page_table[b, j].
        P * ps = the pool's max_seq. The table is scalar-prefetched: the
        k/v BlockSpec index maps dereference it, so each grid program DMAs
        exactly its row's physical page for chunk j. Entry 0 is the scratch
        page (unmapped logical pages; always masked).
    pos: () or (B,) int32 decode positions in *logical* coordinates —
        identical semantics to the contiguous kernel, including pos < 0
        retired rows.
    kc/vc: (m, K, hd) fp cushion covering logical positions [0:m). Allowed
        for BOTH fp and int8 pools: the paged layout stores the shared
        cushion once, batch-free, never in pages (pages below m stay
        scratch-mapped and masked via ``kj >= m``).

    The chunk size is the page size, so against ``flash_decode(bkv=ps)`` on
    the gathered dense cache the online-softmax block sequence is identical
    and the result is bit-exact (the paging property test's gate).
    Returns (B, H, hd).
    """
    B, H, hd = q.shape
    ps, K = k_pages.shape[1], k_pages.shape[2]
    P = page_table.shape[1]
    G = H // K
    quantized = k_scale is not None
    m = 0 if kc is None else kc.shape[0]
    assert ps % 8 == 0, "page_size must be sublane-aligned (multiple of 8)"

    Gp = -(-G // 8) * 8
    q4 = q.reshape(B, K, G, hd)
    if Gp != G:
        q4 = jnp.pad(q4, ((0, 0), (0, 0), (0, Gp - G), (0, 0)))
    mp = m
    if m:
        mp = -(-m // 8) * 8
        if mp != m:
            padc = ((0, mp - m), (0, 0), (0, 0))
            kc = jnp.pad(kc, padc)
            vc = jnp.pad(vc, padc)
    posa = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))
    scale = 1.0 / np.sqrt(hd)

    # index maps receive the scalar-prefetched page table as a trailing ref;
    # only the k/v maps dereference it (logical page j -> physical page)
    in_specs = [
        pl.BlockSpec((1,), lambda b, j, pt: (b // K,)),                  # pos
        pl.BlockSpec((1, 1, Gp, hd), lambda b, j, pt: (b // K, b % K, 0, 0)),
        pl.BlockSpec((1, ps, 1, hd),
                     lambda b, j, pt: (pt[b // K, j], 0, b % K, 0)),
        pl.BlockSpec((1, ps, 1, hd),
                     lambda b, j, pt: (pt[b // K, j], 0, b % K, 0)),
    ]
    args = [posa, q4, k_pages, v_pages]
    if quantized:
        if jnp.ndim(k_scale) == 2:          # per-row (B, K) slot scales
            sspec = pl.BlockSpec((1, 1), lambda b, j, pt: (b // K, b % K))
        else:                               # (K,) shared by the batch
            sspec = pl.BlockSpec((1,), lambda b, j, pt: (b % K,))
        in_specs += [sspec, sspec]
        args += [jnp.asarray(k_scale, jnp.float32),
                 jnp.asarray(v_scale, jnp.float32)]
    if m:
        in_specs += [
            pl.BlockSpec((mp, 1, hd), lambda b, j, pt: (0, b % K, 0)),
            pl.BlockSpec((mp, 1, hd), lambda b, j, pt: (0, b % K, 0))]
        args += [kc, vc]

    def kernel(pt_ref, *refs, **kw):
        del pt_ref      # consumed by the index maps only
        _kernel(*refs, **kw)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * K, P),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, Gp, hd),
                               lambda b, j, pt: (b // K, b % K, 0, 0)),
        scratch_shapes=[pltpu.VMEM((Gp, 1), jnp.float32),
                        pltpu.VMEM((Gp, 1), jnp.float32),
                        pltpu.VMEM((Gp, hd), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(kernel, bkv=ps, n_kv=P, cushion_m=m, mp=mp,
                          quantized=quantized, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, Gp, hd), q.dtype),
        interpret=interpret,
    )(jnp.asarray(page_table, jnp.int32), *args)
    return out[:, :, :G].reshape(B, H, hd)
