"""repro.models"""
