"""Jamba-style hybrid: periods of `period` layers, attention at
`attn_at` indices, Mamba elsewhere; each layer followed by an MLP — MoE on
layers with index % moe_every == moe_offset, dense otherwise.

Layer stack is a scan over *periods* (stacked period params), with the
period's sub-layers unrolled — HLO is O(period), not O(n_layers).

Cushion: attention layers get the paper's prefix-KV; Mamba layers get the
CushionState analogue (trainable initial state). See DESIGN.md §5.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, QuantConfig
from repro.core import quantization as Q
from repro.distributed.sharding import constrain
from repro.models import common as C
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models import transformer as T

Array = jax.Array
Params = Dict[str, Any]

SITES = ("qkv", "o", "mamba_in", "mamba_out", "mlp_in", "down")

# Greedy-search scoring fallback: the hybrid prefix artifact includes Mamba
# recurrent state, and a fixed-shape padded prefix cannot be masked out of a
# recurrence (dead rows would corrupt the state). The search therefore falls
# back to `cushioncache.greedy_search_ref` (full forward per candidate,
# shapes grow with the prefix — one recompile per appended token).
SUPPORTS_PREFIX_KV_SCORING = False

# Continuous batching IS supported: attention leaves batch on axis 1, Mamba
# state leaves on axis 2 (after period & sublayer axes); slot admission
# scatters the whole per-request row (KV + recurrent state) at once.
CACHE_BATCH_AXES = {"k": 1, "v": 1, "h": 2, "conv": 2}

# Attention KV pages; Mamba state stays a dense per-slot row (fixed-size
# recurrent state has nothing to page).
PAGED_KV_LEAVES = ("k", "v")


def layout(cfg: ModelConfig):
    h = cfg.hybrid
    assert cfg.n_layers % h.period == 0
    n_periods = cfg.n_layers // h.period
    kinds = []
    for i in range(h.period):
        mixer = "attn" if i in h.attn_at else "mamba"
        mlp = "moe" if i % h.moe_every == h.moe_offset else "dense"
        kinds.append((mixer, mlp))
    return n_periods, kinds


def period_init(key, cfg: ModelConfig) -> Params:
    _, kinds = layout(cfg)
    p: Params = {"sub": []}
    ks = jax.random.split(key, len(kinds))
    for k, (mixer, mlp) in zip(ks, kinds):
        k1, k2 = jax.random.split(k)
        sub = {"ln1": C.norm_init(cfg), "ln2": C.norm_init(cfg)}
        if mixer == "attn":
            sub["attn"] = C.attn_init(k1, cfg)
        else:
            sub["mamba"] = SSM.mamba_init(k1, cfg)
        if mlp == "moe":
            sub["moe"] = MOE.moe_init(k2, cfg)
        else:
            sub["mlp"] = C.mlp_init(k2, cfg)
        p["sub"].append(sub)
    return p


def init_params(cfg: ModelConfig, rng) -> Params:
    n_periods, _ = layout(cfg)
    k_emb, k_layers = jax.random.split(rng)
    layers = jax.vmap(lambda k: period_init(k, cfg))(
        jax.random.split(k_layers, n_periods))
    p = C.embed_init(k_emb, cfg)
    p["layers"] = layers
    p["ln_f"] = C.norm_init(cfg)
    return p


def _merge_taps(acc: Optional[Dict], new: Optional[Dict]) -> Optional[Dict]:
    if new is None:
        return acc
    if acc is None:
        acc = {}
    for site, st in new.items():
        if site not in acc:
            acc[site] = st
        else:
            a = acc[site]
            merged = {
                "amin": jnp.minimum(a["amin"], st["amin"]),
                "amax": jnp.maximum(a["amax"], st["amax"]),
                "absmax_ch": jnp.maximum(a["absmax_ch"], st["absmax_ch"])
                if a["absmax_ch"].shape == st["absmax_ch"].shape else a["absmax_ch"],
            }
            if "qerr" in a and "qerr" in st:
                merged["qerr"] = a["qerr"] + st["qerr"]
            acc[site] = merged
    return acc


def _period_apply(pp: Params, x: Array, cfg: ModelConfig, qcfg: QuantConfig,
                  lsc: Optional[Params], positions, prefix_kv,
                  mamba_states, collect: bool, n_skip: int,
                  return_states: bool):
    """Apply one period. prefix_kv: dict(k,v) (m,K,hd) or None — shared by
    the period's attention layers. mamba_states: list aligned to mamba
    sublayers (or None)."""
    _, kinds = layout(cfg)
    taps_acc: Optional[Dict] = {} if collect else None
    lb_total = jnp.zeros((), jnp.float32)
    new_states = []
    mi = 0
    for j, (mixer, mlp) in enumerate(kinds):
        sub = pp["sub"][j]
        taps: Optional[Dict] = {} if collect else None
        hn = C.apply_norm(sub["ln1"], x, cfg)
        if collect:
            taps["block_in"] = Q.site_stats(x, n_skip)
        if mixer == "attn":
            o = C.attention_full(sub["attn"], hn, cfg, qcfg, lsc, taps,
                                 positions, prefix_kv=prefix_kv, causal=True,
                                 n_skip=n_skip)
        else:
            st = mamba_states[mi] if mamba_states is not None else None
            if return_states:
                o, new_st = SSM.apply_mamba(sub["mamba"], hn, cfg, qcfg, lsc,
                                            taps, n_skip, init_state=st,
                                            return_state=True)
                new_states.append(new_st)
            else:
                o = SSM.apply_mamba(sub["mamba"], hn, cfg, qcfg, lsc, taps,
                                    n_skip, init_state=st)
            mi += 1
        x = x + o
        hn = C.apply_norm(sub["ln2"], x, cfg)
        if mlp == "moe":
            y, lb = MOE.apply_moe(sub["moe"], hn, cfg, qcfg, lsc, taps, n_skip)
            lb_total = lb_total + lb
        else:
            y = C.apply_mlp(sub["mlp"], hn, cfg, qcfg, lsc, taps, n_skip)
        x = constrain(x + y, "B")
        if collect:
            taps_acc = _merge_taps(taps_acc, taps)
    return x, taps_acc, lb_total, new_states


def n_mamba_per_period(cfg: ModelConfig) -> int:
    _, kinds = layout(cfg)
    return sum(1 for m, _ in kinds if m == "mamba")


def cushion_zeros(cfg: ModelConfig, m: int, dtype=None) -> Params:
    """Prefix KV for the attention layers + initial states for the Mamba
    layers (batch-free; broadcast at use). Defaults to the model compute
    dtype (see transformer.cushion_zeros)."""
    dtype = C.dtype_of(cfg) if dtype is None else dtype
    n_periods, _ = layout(cfg)
    K, hd = cfg.n_kv_heads, cfg.head_dim
    nm = n_mamba_per_period(cfg)
    inner, d_state, d_conv, _ = SSM.dims(cfg)
    return {
        "kv": {"k": jnp.zeros((n_periods, m, K, hd), dtype),
               "v": jnp.zeros((n_periods, m, K, hd), dtype)},
        "state": {"h": jnp.zeros((n_periods, nm, inner, d_state), dtype),
                  "conv": jnp.zeros((n_periods, nm, d_conv - 1, inner), dtype)},
    }


def forward(params: Params, tokens: Array, cfg: ModelConfig,
            qcfg: QuantConfig, *, scales: Optional[Params] = None,
            cushion: Optional[Params] = None, collect: bool = False,
            n_skip: int = 0, prepend_embeds: Optional[Array] = None,
            remat: bool = True, return_cache: bool = False):
    x = C.embed_tokens(params, tokens, cfg)
    if prepend_embeds is not None:
        x = jnp.concatenate([prepend_embeds.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    n_periods, kinds = layout(cfg)
    nm = n_mamba_per_period(cfg)
    m = 0 if cushion is None else cushion["kv"]["k"].shape[1]
    positions = m + jnp.arange(S)
    lscales = C.resolve_scales(scales, SITES, n_periods, qcfg)

    if cushion is not None:
        pre_kv = cushion["kv"]
        mstates = cushion["state"]
    else:
        K, hd = cfg.n_kv_heads, cfg.head_dim
        pre_kv = {"k": jnp.zeros((n_periods, 0, K, hd), x.dtype),
                  "v": jnp.zeros((n_periods, 0, K, hd), x.dtype)}
        mstates = None

    def body(h, xs):
        if mstates is None:
            pp, lsc, pkv = xs
            mst = None
        else:
            pp, lsc, pkv, mst_raw = xs
            mst = [{"h": mst_raw["h"][i], "conv": mst_raw["conv"][i]}
                   for i in range(nm)]
        h, taps, lb, new_st = _period_apply(
            pp, h, cfg, qcfg, lsc, positions, pkv, mst, collect, n_skip,
            return_states=return_cache)
        ys = ((taps if collect else {}), lb)
        if return_cache:
            ys = ys + ({"h": jnp.stack([s["h"] for s in new_st]),
                        "conv": jnp.stack([s["conv"] for s in new_st])},)
        return h, ys

    if remat:
        body = jax.checkpoint(body)
    xs = (params["layers"], lscales, pre_kv)
    if mstates is not None:
        xs = xs + (mstates,)
    x, ys = jax.lax.scan(body, x, xs)
    layer_taps, lbs = ys[0], ys[1]
    x = C.apply_norm(params["ln_f"], x, cfg)
    head_taps: Optional[Dict] = {} if collect else None
    logits = C.lm_head(params, x, cfg, qcfg, scales, head_taps, n_skip)
    taps: Dict = {"lb_loss": jnp.mean(lbs)}
    if collect:
        taps.update({"layers": layer_taps, **(head_taps or {}),
                     "final_in": Q.site_stats(x, n_skip)})
    if return_cache:
        return logits, taps, ys[2]
    return logits, taps


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None,
               kv_dtype=None, prefix_len: int = 0,
               per_slot_scales: bool = False) -> Params:
    """kv_dtype "int8": attention KV stored int8 with per-(period,head)
    scales — per-slot (P, batch, K) when ``per_slot_scales`` (continuous
    pool) — and a protected fp cushion block (see transformer.init_cache);
    Mamba states always stay fp."""
    dt = dtype or C.dtype_of(cfg)
    n_periods, _ = layout(cfg)
    nm = n_mamba_per_period(cfg)
    K, hd = cfg.n_kv_heads, cfg.head_dim
    inner, d_state, d_conv, _ = SSM.dims(cfg)
    cache = {
        "k": jnp.zeros((n_periods, batch, max_seq, K, hd), dt),
        "v": jnp.zeros((n_periods, batch, max_seq, K, hd), dt),
        "h": jnp.zeros((n_periods, nm, batch, inner, d_state), jnp.float32),
        "conv": jnp.zeros((n_periods, nm, batch, d_conv - 1, inner), dt),
    }
    if kv_dtype is not None:
        if kv_dtype not in ("int8", jnp.int8):
            raise ValueError(f"unsupported kv_dtype {kv_dtype!r}")
        cache["k"] = cache["k"].astype(jnp.int8)
        cache["v"] = cache["v"].astype(jnp.int8)
        sshape = ((n_periods, batch, K) if per_slot_scales
                  else (n_periods, K))
        cache.update({
            "k_scale": jnp.ones(sshape, jnp.float32),
            "v_scale": jnp.ones(sshape, jnp.float32),
            "kc": jnp.zeros((n_periods, prefix_len, K, hd), dt),
            "vc": jnp.zeros((n_periods, prefix_len, K, hd), dt)})
    return cache


def cache_roles(cfg: ModelConfig, kv_dtype=None,
                per_slot_scales: bool = False) -> Params:
    """Serve-pool sharding roles (see transformer.cache_roles): attention
    KV (P, B, S, K, hd) shards its heads axis on "M"; the Mamba state
    shards its channel axes — h (P, nm, B, inner, d_state) on inner, conv
    (P, nm, B, d_conv-1, inner) on inner — mirroring the mamba/w_x "M"
    param rules so the recurrence stays shard-local. int8 scales shard
    with their heads axis; the fp cushion block is replicated."""
    kv = (None, "B", None, "M", None)
    roles = {"k": kv, "v": kv,
             "h": (None, None, "B", "M", None),
             "conv": (None, None, "B", None, "M")}
    if kv_dtype is not None:
        sc = (None, "B", "M") if per_slot_scales else (None, "M")
        roles.update({"k_scale": sc, "v_scale": sc, "kc": (), "vc": ()})
    return roles


def prefill(params: Params, tokens: Array, cache: Params, cfg: ModelConfig,
            qcfg: QuantConfig, *, scales: Optional[Params] = None,
            cushion: Optional[Params] = None,
            prepend_embeds: Optional[Array] = None, remat: bool = False):
    """Full-pass prefill that also materializes the cache. For simplicity it
    recomputes per-period KV by re-running attention sublayers with
    return_kv; batch sizes at prefill are modest."""
    x = C.embed_tokens(params, tokens, cfg)
    if prepend_embeds is not None:
        x = jnp.concatenate([prepend_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    n_periods, kinds = layout(cfg)
    nm = n_mamba_per_period(cfg)
    m = 0 if cushion is None else cushion["kv"]["k"].shape[1]
    positions = m + jnp.arange(S)
    lscales = C.resolve_scales(scales, SITES, n_periods, qcfg)
    K, hd = cfg.n_kv_heads, cfg.head_dim
    if cushion is not None:
        pre_kv = cushion["kv"]
        mst0 = cushion["state"]
    else:
        pre_kv = {"k": jnp.zeros((n_periods, 0, K, hd), x.dtype),
                  "v": jnp.zeros((n_periods, 0, K, hd), x.dtype)}
        mst0 = None

    def body(h, xs):
        if mst0 is None:
            pp, lsc, pkv = xs
            mst = None
        else:
            pp, lsc, pkv, msr = xs
            mst = [{"h": msr["h"][i], "conv": msr["conv"][i]}
                   for i in range(nm)]
        new_kv = None
        new_states = []
        mi = 0
        for j, (mixer, mlp) in enumerate(kinds):
            sub = pp["sub"][j]
            hn = C.apply_norm(sub["ln1"], h, cfg)
            if mixer == "attn":
                o, new_kv = C.attention_full(sub["attn"], hn, cfg, qcfg, lsc,
                                             None, positions, prefix_kv=pkv,
                                             causal=True, return_kv=True)
            else:
                st = mst[mi] if mst is not None else None
                o, nst = SSM.apply_mamba(sub["mamba"], hn, cfg, qcfg, lsc,
                                         None, 0, init_state=st,
                                         return_state=True)
                new_states.append(nst)
                mi += 1
            h = h + o
            hn = C.apply_norm(sub["ln2"], h, cfg)
            if mlp == "moe":
                y, _ = MOE.apply_moe(sub["moe"], hn, cfg, qcfg, lsc, None)
            else:
                y = C.apply_mlp(sub["mlp"], hn, cfg, qcfg, lsc, None)
            h = constrain(h + y, "B")
        ys = (new_kv,
              {"h": jnp.stack([s["h"] for s in new_states]),
               "conv": jnp.stack([s["conv"] for s in new_states])})
        return h, ys

    if remat:
        body = jax.checkpoint(body)
    xs = (params["layers"], lscales, pre_kv)
    if mst0 is not None:
        xs = xs + (mst0,)
    x, ((ks, vs), mstates) = jax.lax.scan(body, x, xs)

    # write cushion kv then prompt kv into cache
    if cushion is not None:
        if "kc" in cache:
            # quantized cache: cushion block protected in fp (kc/vc)
            assert cache["kc"].shape[1] == m, \
                f"cache prefix_len {cache['kc'].shape[1]} != cushion len {m}"
            cache = dict(cache)
            cache["kc"] = cushion["kv"]["k"].astype(cache["kc"].dtype)
            cache["vc"] = cushion["kv"]["v"].astype(cache["vc"].dtype)
        else:
            ck = jnp.broadcast_to(cushion["kv"]["k"][:, None],
                                  (n_periods, B, m, K, hd)).astype(cache["k"].dtype)
            cv = jnp.broadcast_to(cushion["kv"]["v"][:, None],
                                  (n_periods, B, m, K, hd)).astype(cache["v"].dtype)
            cache = dict(cache)
            cache["k"] = jax.lax.dynamic_update_slice(cache["k"], ck, (0, 0, 0, 0, 0))
            cache["v"] = jax.lax.dynamic_update_slice(cache["v"], cv, (0, 0, 0, 0, 0))
    cache = T.write_prompt_kv(cache, ks, vs, m)
    cache["h"] = mstates["h"]
    cache["conv"] = mstates["conv"].astype(cache["conv"].dtype)
    x = C.apply_norm(params["ln_f"], x, cfg)
    logits = C.lm_head(params, x[:, -1:], cfg, qcfg, scales, None)
    return logits, cache, jnp.asarray(m + S, jnp.int32)


def decode_step(params: Params, token: Array, pos: Array, cache: Params,
                cfg: ModelConfig, qcfg: QuantConfig, *,
                scales: Optional[Params] = None):
    """One decode step; pos may be () shared or (B,) per-row. Attention
    sublayers mask/write per-row (attention_decode_kv); Mamba recurrences
    are position-free and advance every row — a retired slot's state takes
    dummy-token updates and is rebuilt wholesale at recycle by prefill."""
    x = C.embed_tokens(params, token[:, None], cfg)
    n_periods, kinds = layout(cfg)
    nm = n_mamba_per_period(cfg)
    lscales = C.resolve_scales(scales, SITES, n_periods, qcfg)

    kv_keys = [k for k in ("k", "v", "k_scale", "v_scale", "kc", "vc",
                           "page_table")
               if k in cache]

    def body(h, xs):
        pp, lsc, kvd, mh, mconv = xs
        mi = 0
        for j, (mixer, mlp) in enumerate(kinds):
            sub = pp["sub"][j]
            hn = C.apply_norm(sub["ln1"], h, cfg)
            if mixer == "attn":
                o, kvd = C.attention_decode_kv(sub["attn"], hn, kvd, pos,
                                               cfg, qcfg, lsc, None)
            else:
                st = {"h": mh[mi], "conv": mconv[mi]}
                o, nst = SSM.decode_mamba(sub["mamba"], hn, st, cfg, qcfg,
                                          lsc)
                mh = mh.at[mi].set(nst["h"])
                mconv = mconv.at[mi].set(nst["conv"].astype(mconv.dtype))
                mi += 1
            h = h + o
            hn = C.apply_norm(sub["ln2"], h, cfg)
            if mlp == "moe":
                y, _ = MOE.apply_moe(sub["moe"], hn, cfg, qcfg, lsc, None)
            else:
                y = C.apply_mlp(sub["mlp"], hn, cfg, qcfg, lsc, None)
            h = h + y
        return h, (kvd, mh, mconv)

    x, (kvs, mh, mconv) = jax.lax.scan(
        body, x, (params["layers"], lscales,
                  {k: cache[k] for k in kv_keys},
                  cache["h"], cache["conv"]))
    cache = dict(kvs)
    cache["h"], cache["conv"] = mh, mconv
    x = C.apply_norm(params["ln_f"], x, cfg)
    logits = C.lm_head(params, x, cfg, qcfg, scales, None)
    return logits[:, 0], cache


def loss_fn(params: Params, tokens: Array, labels: Array, cfg: ModelConfig,
            qcfg: QuantConfig, *, scales=None, cushion=None,
            collect: bool = False, n_skip: int = 0, remat: bool = True,
            lam: float = 0.0):
    logits, taps = forward(params, tokens, cfg, qcfg, scales=scales,
                           cushion=cushion, collect=collect or lam > 0,
                           n_skip=n_skip, remat=remat)
    if n_skip:
        logits = logits[:, n_skip:]
        labels = labels[:, n_skip:]
    ce = C.cross_entropy(logits, labels)
    loss = ce + cfg.moe.load_balance_coef * taps["lb_loss"]
    aux = {"ce": ce, "taps": taps, "lb": taps["lb_loss"]}
    if lam > 0 or collect:
        qerr = T.total_qerr(taps)
        aux["qerr"] = qerr
        if lam > 0:
            loss = loss + lam * qerr
    return loss, aux


def placeholder_all_scales(cfg: ModelConfig) -> Params:
    n_periods, _ = layout(cfg)
    sc = C.placeholder_scales(SITES, n_periods)
    sc["head"] = Q.SiteScale(scale=jnp.ones(()), zero=jnp.zeros(()))
    return sc
