"""Mixture-of-Experts decoder (olmoe top-8; arctic 128e top-2 + dense
residual branch).

Expert dispatch is GShard/Switch-style capacity-based dense dispatch — the
canonical partitionable formulation under GSPMD: experts shard on `model`,
tokens on batch axes; the dispatch einsums lower to all-to-all-like
collectives. Capacity factor 1.25, dropped tokens pass through the residual.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, QuantConfig
from repro.core import quantization as Q
from repro.distributed.sharding import constrain
from repro.models import common as C
from repro.models import transformer as T

Array = jax.Array
Params = Dict[str, Any]

SITES = C.ATTN_SITES + ("mlp_in", "down")

# Attention-KV-only prefix artifact -> eligible for the greedy-search
# KV-reuse scoring fast path (ModelAPI.score_candidates). Note the scoring
# contract for MoE: expert capacity is derived from the *scored* sequence
# ([candidate; sample]), and the "down" site qerr covers only that
# sequence's expert traffic — prefix tokens never re-enter the experts,
# matching deployment (the reference full-forward scorer routes prefix
# tokens through the experts as a side effect of recomputing them).
SUPPORTS_PREFIX_KV_SCORING = True


def moe_init(key, cfg: ModelConfig) -> Params:
    moe = cfg.moe
    dt = C.dtype_of(cfg)
    E, D, F = moe.num_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 5)
    std_in = 1.0 / np.sqrt(D)
    std_out = 1.0 / np.sqrt(F) / np.sqrt(2 * cfg.n_layers)
    p = {
        "router": (jax.random.normal(ks[0], (D, E), jnp.float32) * std_in),
        "w_up": (jax.random.normal(ks[1], (E, D, F), jnp.float32) * std_in).astype(dt),
        "w_gate": (jax.random.normal(ks[2], (E, D, F), jnp.float32) * std_in).astype(dt),
        "w_down": (jax.random.normal(ks[3], (E, F, D), jnp.float32) * std_out).astype(dt),
    }
    if moe.dense_residual_ff:
        p["residual"] = C.mlp_init(ks[4], cfg, d_ff=moe.dense_residual_ff)
    return p


def capacity(seq: int, cfg: ModelConfig) -> int:
    moe = cfg.moe
    c = int(np.ceil(seq * moe.top_k / moe.num_experts * moe.capacity_factor))
    c = min(c, seq * moe.top_k)
    return max(4, int(np.ceil(c / 4)) * 4)


def apply_moe(p: Params, x: Array, cfg: ModelConfig, qcfg: QuantConfig,
              scales: Optional[Params], taps: Optional[Dict],
              n_skip: int = 0) -> Tuple[Array, Array]:
    """Returns (y, load_balance_loss)."""
    moe = cfg.moe
    B, S, D = x.shape
    E, K = moe.num_experts, moe.top_k
    Cp = capacity(S, cfg)

    gate_logits = x.astype(jnp.float32) @ p["router"]          # (B,S,E)
    gate_logits = constrain(gate_logits, "B", None, "M")
    probs = jax.nn.softmax(gate_logits, axis=-1)
    top_w, top_idx = jax.lax.top_k(probs, K)                    # (B,S,K)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    # Switch-style load-balance loss: E * sum_e mean(frac_e) * mean(prob_e)
    onehot = jax.nn.one_hot(top_idx, E, dtype=jnp.float32)      # (B,S,K,E)
    frac = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1))       # (E,)
    lb = E * jnp.sum(frac * jnp.mean(probs, axis=(0, 1)))

    # capacity assignment: position of each (token, k) within its expert
    flat = onehot.reshape(B, S * K, E)
    pos = jnp.cumsum(flat, axis=1) - 1.0                         # (B,S*K,E)
    keep = (pos < Cp) * flat
    slot = jax.nn.one_hot(pos, Cp, dtype=jnp.float32) * keep[..., None]
    disp = slot.reshape(B, S, K, E, Cp).astype(x.dtype)          # (B,S,K,E,C)
    disp = constrain(disp, "B", None, None, "M", None)
    comb = jnp.einsum("bsk,bskec->bsec", top_w.astype(x.dtype), disp)
    disp_tok = jnp.sum(disp, axis=2)                             # (B,S,E,C)

    if taps is not None:
        taps["mlp_in"] = {
            "qerr": Q.site_qerr(x, qcfg, C.get_site(scales, "mlp_in"), n_skip),
            **Q.site_stats(x, n_skip)}

    xin = jnp.einsum("bsec,bsd->ebcd", disp_tok, x)              # (E,B,C,D)
    xin = constrain(xin, "M", "B", None, None)
    qs = C.get_site(scales, "mlp_in")
    xq = Q.act_fake_quant(xin, qcfg, qs.scale if qs else None,
                          qs.zero if qs else None)
    up = jnp.einsum("ebcd,edf->ebcf", xq, Q.weight_fake_quant(p["w_up"], qcfg))
    gate = jnp.einsum("ebcd,edf->ebcf", xq,
                      Q.weight_fake_quant(p["w_gate"], qcfg))
    h = jax.nn.silu(gate) * up
    h = constrain(h, "M", "B", None, None)
    if taps is not None:
        taps["down"] = {
            "qerr": Q.site_qerr(h, qcfg, C.get_site(scales, "down"), 0),
            **Q.site_stats(h, 0)}
    qs2 = C.get_site(scales, "down")
    hq = Q.act_fake_quant(h, qcfg, qs2.scale if qs2 else None,
                          qs2.zero if qs2 else None)
    out = jnp.einsum("ebcf,efd->ebcd", hq,
                     Q.weight_fake_quant(p["w_down"], qcfg))
    y = jnp.einsum("bsec,ebcd->bsd", comb, out)
    y = constrain(y, "B")

    if "residual" in p:
        # Arctic: dense FFN branch in parallel with the MoE branch
        y = y + C.apply_mlp(p["residual"], x, cfg, qcfg, scales, None, n_skip)
    return y, lb


def layer_init(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {"ln1": C.norm_init(cfg), "attn": C.attn_init(k1, cfg),
            "ln2": C.norm_init(cfg), "moe": moe_init(k2, cfg)}


def init_params(cfg: ModelConfig, rng) -> Params:
    k_emb, k_layers = jax.random.split(rng)
    layers = jax.vmap(lambda k: layer_init(k, cfg))(
        jax.random.split(k_layers, cfg.n_layers))
    p = C.embed_init(k_emb, cfg)
    p["layers"] = layers
    p["ln_f"] = C.norm_init(cfg)
    return p


def _empty_prefix(cfg: ModelConfig, dtype) -> Params:
    return {"k": jnp.zeros((cfg.n_layers, 0, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((cfg.n_layers, 0, cfg.n_kv_heads, cfg.head_dim), dtype)}


def forward(params: Params, tokens: Array, cfg: ModelConfig,
            qcfg: QuantConfig, *, scales: Optional[Params] = None,
            cushion: Optional[Params] = None, collect: bool = False,
            n_skip: int = 0, prepend_embeds: Optional[Array] = None,
            remat: bool = True, prefix_valid: Optional[Array] = None,
            pos_offset: Optional[Array] = None) -> Tuple[Array, Dict]:
    x = C.embed_tokens(params, tokens, cfg)
    if prepend_embeds is not None:
        x = jnp.concatenate([prepend_embeds.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    m = 0 if cushion is None else cushion["kv"]["k"].shape[1]
    positions = (m if pos_offset is None else pos_offset) + jnp.arange(S)
    lscales = C.resolve_scales(scales, SITES, cfg.n_layers, qcfg)
    pre = cushion["kv"] if cushion is not None else _empty_prefix(cfg, x.dtype)

    def body(h, xs):
        lp, lsc, lpre = xs
        taps: Optional[Dict] = {} if collect else None
        hn = C.apply_norm(lp["ln1"], h, cfg)
        if collect:
            taps["block_in"] = Q.site_stats(h, n_skip)
        a = C.attention_full(lp["attn"], hn, cfg, qcfg, lsc, taps, positions,
                             prefix_kv=lpre, causal=True, n_skip=n_skip,
                             prefix_valid=prefix_valid)
        h = h + a
        hn = C.apply_norm(lp["ln2"], h, cfg)
        y, lb = apply_moe(lp["moe"], hn, cfg, qcfg, lsc, taps, n_skip)
        h = constrain(h + y, "B")
        return h, ((taps if collect else {}), lb)

    if remat:
        body = jax.checkpoint(body)
    x, (layer_taps, lbs) = jax.lax.scan(body, x, (params["layers"], lscales, pre))
    x = C.apply_norm(params["ln_f"], x, cfg)
    head_taps: Optional[Dict] = {} if collect else None
    logits = C.lm_head(params, x, cfg, qcfg, scales, head_taps, n_skip)
    taps: Dict = {}
    if collect:
        taps = {"layers": layer_taps, **(head_taps or {}),
                "final_in": Q.site_stats(x, n_skip)}
    taps["lb_loss"] = jnp.mean(lbs)
    return logits, taps


init_cache = T.init_cache
cushion_zeros = T.cushion_zeros
write_cushion_to_cache = T.write_cushion_to_cache
finalize_staged_kv = T.finalize_staged_kv
cache_roles = T.cache_roles
placeholder_all_scales = T.placeholder_all_scales
CACHE_BATCH_AXES = T.CACHE_BATCH_AXES
PAGED_KV_LEAVES = T.PAGED_KV_LEAVES
SUPPORTS_CHUNKED_PREFILL = T.SUPPORTS_CHUNKED_PREFILL


def prefill(params: Params, tokens: Array, cache: Params, cfg: ModelConfig,
            qcfg: QuantConfig, *, scales: Optional[Params] = None,
            cushion: Optional[Params] = None,
            prepend_embeds: Optional[Array] = None,
            remat: bool = False,
            pos_offset: Optional[int] = None) -> Tuple[Array, Params, Array]:
    x = C.embed_tokens(params, tokens, cfg)
    if prepend_embeds is not None:
        x = jnp.concatenate([prepend_embeds.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    if pos_offset is not None:
        # chunk-resume (see transformer.prefill): read the cushion + earlier
        # chunks back out of the B=1 fp staging row as the visible prefix
        if cushion is not None:
            raise ValueError("chunk-resume prefill attaches the cushion on "
                             "chunk 0 only (pos_offset excludes cushion)")
        if "k_scale" in cache or cache["k"].shape[1] != 1:
            raise ValueError("chunk-resume prefill needs a B=1 fp staging row")
        m = int(pos_offset)
        pre = {"k": jax.lax.slice_in_dim(cache["k"], 0, m, axis=2)[:, 0],
               "v": jax.lax.slice_in_dim(cache["v"], 0, m, axis=2)[:, 0]}
    else:
        cache, m = write_cushion_to_cache(cache, cushion)
        pre = (cushion["kv"] if cushion is not None
               else _empty_prefix(cfg, x.dtype))
    positions = m + jnp.arange(S)
    lscales = C.resolve_scales(scales, SITES, cfg.n_layers, qcfg)

    def body(h, xs):
        lp, lsc, lpre = xs
        hn = C.apply_norm(lp["ln1"], h, cfg)
        a, kv = C.attention_full(lp["attn"], hn, cfg, qcfg, lsc, None,
                                 positions, prefix_kv=lpre, causal=True,
                                 return_kv=True)
        h = h + a
        hn = C.apply_norm(lp["ln2"], h, cfg)
        y, _ = apply_moe(lp["moe"], hn, cfg, qcfg, lsc, None)
        h = constrain(h + y, "B")
        return h, kv

    if remat:
        body = jax.checkpoint(body)
    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], lscales, pre))
    cache = T.write_prompt_kv(cache, ks, vs, m)
    x = C.apply_norm(params["ln_f"], x, cfg)
    logits = C.lm_head(params, x[:, -1:], cfg, qcfg, scales, None)
    return logits, cache, jnp.asarray(m + S, jnp.int32)


def decode_step(params: Params, token: Array, pos: Array, cache: Params,
                cfg: ModelConfig, qcfg: QuantConfig, *,
                scales: Optional[Params] = None) -> Tuple[Array, Params]:
    """One decode step; pos may be () shared or (B,) per-row (continuous
    batching). Expert capacity/dispatch is per-row at S=1, so lock-step
    decode of independent slots stays row-local."""
    x = C.embed_tokens(params, token[:, None], cfg)
    lscales = C.resolve_scales(scales, SITES, cfg.n_layers, qcfg)

    def body(h, xs):
        lp, lsc, kvc = xs
        hn = C.apply_norm(lp["ln1"], h, cfg)
        a, kvc = C.attention_decode_kv(lp["attn"], hn, kvc, pos, cfg, qcfg,
                                       lsc, None)
        h = h + a
        hn = C.apply_norm(lp["ln2"], h, cfg)
        y, _ = apply_moe(lp["moe"], hn, cfg, qcfg, lsc, None)
        h = h + y
        return h, kvc

    x, cache = jax.lax.scan(body, x, (params["layers"], lscales, cache))
    x = C.apply_norm(params["ln_f"], x, cfg)
    logits = C.lm_head(params, x, cfg, qcfg, scales, None)
    return logits[:, 0], cache


def loss_fn(params: Params, tokens: Array, labels: Array, cfg: ModelConfig,
            qcfg: QuantConfig, *, scales=None, cushion=None,
            collect: bool = False, n_skip: int = 0, remat: bool = True,
            lam: float = 0.0):
    logits, taps = forward(params, tokens, cfg, qcfg, scales=scales,
                           cushion=cushion, collect=collect or lam > 0,
                           n_skip=n_skip, remat=remat)
    if n_skip:
        logits = logits[:, n_skip:]
        labels = labels[:, n_skip:]
    ce = C.cross_entropy(logits, labels)
    loss = ce + cfg.moe.load_balance_coef * taps["lb_loss"]
    aux = {"ce": ce, "taps": taps, "lb": taps["lb_loss"]}
    if lam > 0 or collect:
        qerr = T.total_qerr(taps)
        aux["qerr"] = qerr
        if lam > 0:
            loss = loss + lam * qerr
    return loss, aux
