"""InternVL2-style VLM backbone: standard dense LM (InternLM2-arch) with a
STUB vision frontend — inputs are precomputed patch embeddings (B, P, D)
prepended to the token embeddings (assignment: frontend is a stub).

The cushion prefix sits *before* the patch embeddings, so patches and text
both benefit from the sink (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax.numpy as jnp

from repro.configs.base import ModelConfig, QuantConfig
from repro.models import common as C
from repro.models import transformer as T

Array = Any
Params = Dict[str, Any]

SITES = T.SITES
# LM backbone with a pure-KV prefix artifact: the greedy-search fast path
# prefills the token prefix once (no patches — the cushion sits before
# them) and scores candidates as [cand_embed; patches; text] against it.
SUPPORTS_PREFIX_KV_SCORING = True
init_params = T.init_params
init_cache = T.init_cache
cushion_zeros = T.cushion_zeros
decode_step = T.decode_step
cache_roles = T.cache_roles
placeholder_all_scales = T.placeholder_all_scales
# decode is a plain token LM (patches enter at prefill only), so VLM slots
# batch-continuously exactly like dense ones
CACHE_BATCH_AXES = T.CACHE_BATCH_AXES
PAGED_KV_LEAVES = T.PAGED_KV_LEAVES


def forward(params: Params, tokens: Array, cfg: ModelConfig,
            qcfg: QuantConfig, *, patches: Array,
            scales: Optional[Params] = None, cushion: Optional[Params] = None,
            collect: bool = False, n_skip: int = 0, remat: bool = True,
            prefix_valid=None, pos_offset=None):
    """tokens: (B, S_text); patches: (B, P, D). Sequence = [patches; text]."""
    return T.forward(params, tokens, cfg, qcfg, scales=scales,
                     cushion=cushion, collect=collect, n_skip=n_skip,
                     prepend_embeds=patches, remat=remat,
                     prefix_valid=prefix_valid, pos_offset=pos_offset)


def prefill(params: Params, tokens: Array, cache: Params, cfg: ModelConfig,
            qcfg: QuantConfig, *, patches: Array,
            scales: Optional[Params] = None, cushion: Optional[Params] = None,
            remat: bool = False):
    return T.prefill(params, tokens, cache, cfg, qcfg, scales=scales,
                     cushion=cushion, prepend_embeds=patches, remat=remat)


def loss_fn(params: Params, tokens: Array, labels: Array, cfg: ModelConfig,
            qcfg: QuantConfig, *, patches: Array, scales=None, cushion=None,
            collect: bool = False, remat: bool = True, lam: float = 0.0):
    """CE over the text positions only (patch positions carry no labels)."""
    P = patches.shape[1]
    logits, taps = T.forward(params, tokens, cfg, qcfg, scales=scales,
                             cushion=cushion, collect=collect or lam > 0,
                             n_skip=P, prepend_embeds=patches, remat=remat)
    logits = logits[:, P:]
    ce = C.cross_entropy(logits, labels)
    loss = ce
    aux = {"ce": ce, "taps": taps}
    if lam > 0 or collect:
        qerr = T.total_qerr(taps)
        aux["qerr"] = qerr
        if lam > 0:
            loss = loss + lam * qerr
    return loss, aux
