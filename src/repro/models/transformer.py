"""Dense decoder-only transformer (llama-style; qwen QKV-bias variant via
config). Defines the canonical model API all families follow:

    init_params(cfg, rng)                     -> params
    forward(params, tokens, cfg, qcfg, ...)   -> (logits, taps)   # full seq
    init_cache(cfg, B, Smax, ...)             -> cache
    prefill(params, tokens, cache, ...)       -> (logits, cache, pos)
    decode_step(params, token, pos, cache,..) -> (logits, cache)

The layer stack is a `lax.scan` over stacked per-layer params so the lowered
HLO is O(1) in depth (critical for the 95-layer dry-runs), with optional
remat on the scan body.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, QuantConfig
from repro.core import quantization as Q
from repro.distributed.sharding import constrain
from repro.models import common as C

Array = jax.Array
Params = Dict[str, Any]

SITES = C.ATTN_SITES + C.MLP_SITES  # ("qkv", "o", "mlp_in", "down")

# The prefix deployment artifact is pure attention KV, so the greedy-search
# fast path can prefill the shared prefix once and score every candidate
# against the cached block (ModelAPI.score_candidates).
SUPPORTS_PREFIX_KV_SCORING = True

# prefill() accepts pos_offset to resume a partially-written fp cache row:
# the scheduler's chunked admission replays a prompt chunk-by-chunk, reading
# everything before the chunk (cushion included) back out of the row as the
# fully-visible prefix. Families whose prompt pass is not a pure causal
# attention-KV scan (ssm state, encdec cross-KV, vlm patch prepend) stay on
# blocking admission.
SUPPORTS_CHUNKED_PREFILL = True

# Continuous-batching slot layout: batch axis of every per-request cache
# leaf (init_cache puts batch second, after the layer axis). The scheduler
# scatters a B=1 prefilled cache row into its slot along these axes and
# relies on decode_step accepting a (B,) per-row pos vector.
CACHE_BATCH_AXES = {"k": 1, "v": 1}

# Leaves the paged pool (ContinuousEngine(paged=True)) re-lays into a flat
# page store + per-slot page table instead of slot-scattering; every other
# CACHE_BATCH_AXES entry keeps its dense per-slot row. Families without
# this marker (ssm, encdec) have no pageable sequence cache.
PAGED_KV_LEAVES = ("k", "v")


def layer_init(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {"ln1": C.norm_init(cfg), "attn": C.attn_init(k1, cfg),
            "ln2": C.norm_init(cfg), "mlp": C.mlp_init(k2, cfg)}


def init_params(cfg: ModelConfig, rng) -> Params:
    k_emb, k_layers = jax.random.split(rng)
    layers = jax.vmap(lambda k: layer_init(k, cfg))(
        jax.random.split(k_layers, cfg.n_layers))
    p = C.embed_init(k_emb, cfg)
    p["layers"] = layers
    p["ln_f"] = C.norm_init(cfg)
    return p


def _block(lp: Params, x: Array, cfg: ModelConfig, qcfg: QuantConfig,
           lsc: Optional[Params], lpre: Optional[Params], positions: Array,
           collect: bool, n_skip: int,
           prefix_valid: Optional[Array] = None) -> Tuple[Array, Dict]:
    taps: Optional[Dict] = {} if collect else None
    h = C.apply_norm(lp["ln1"], x, cfg)
    if collect:
        taps["block_in"] = Q.site_stats(x, n_skip)
    a = C.attention_full(lp["attn"], h, cfg, qcfg, lsc, taps, positions,
                         prefix_kv=lpre, causal=True, n_skip=n_skip,
                         prefix_valid=prefix_valid)
    x = x + a
    h = C.apply_norm(lp["ln2"], x, cfg)
    m = C.apply_mlp(lp["mlp"], h, cfg, qcfg, lsc, taps, n_skip)
    x = x + m
    x = constrain(x, "B")
    return x, (taps if collect else {})


def forward(params: Params, tokens: Array, cfg: ModelConfig,
            qcfg: QuantConfig, *, scales: Optional[Params] = None,
            cushion: Optional[Params] = None, collect: bool = False,
            n_skip: int = 0, prepend_embeds: Optional[Array] = None,
            remat: bool = True, prefix_valid: Optional[Array] = None,
            pos_offset: Optional[Array] = None) -> Tuple[Array, Dict]:
    """Full-sequence causal forward. cushion: {"kv": {"k": (L,m,K,hd), ...}}.
    prepend_embeds (B,P,D): extra embeddings placed before the token
    embeddings (VLM patches / greedy-search candidate activations).

    prefix_valid / pos_offset serve the compile-once search scoring path:
    the cushion KV is padded to a fixed shape, prefix_valid ((m,) bool)
    masks the dead rows, and pos_offset (dynamic scalar) replaces the static
    prefix length as the RoPE position origin of x's tokens."""
    x = C.embed_tokens(params, tokens, cfg)
    if prepend_embeds is not None:
        x = jnp.concatenate([prepend_embeds.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    m = 0 if cushion is None else cushion["kv"]["k"].shape[1]
    positions = (m if pos_offset is None else pos_offset) + jnp.arange(S)

    lscales = C.resolve_scales(scales, SITES, cfg.n_layers, qcfg)
    head_sc = scales

    def body(h, xs):
        lp, lsc, lpre = xs
        h, taps = _block(lp, h, cfg, qcfg, lsc, lpre, positions, collect,
                         n_skip, prefix_valid=prefix_valid)
        return h, taps

    if remat:
        body = jax.checkpoint(body)
    pre = cushion["kv"] if cushion is not None else None
    xs = (params["layers"], lscales, pre)
    if pre is None:
        # scan needs uniform xs; replace None with per-layer empty marker
        xs = (params["layers"], lscales,
              {"k": jnp.zeros((cfg.n_layers, 0, cfg.n_kv_heads, cfg.head_dim),
                              x.dtype),
               "v": jnp.zeros((cfg.n_layers, 0, cfg.n_kv_heads, cfg.head_dim),
                              x.dtype)})
    x, layer_taps = jax.lax.scan(body, x, xs)
    x = C.apply_norm(params["ln_f"], x, cfg)
    head_taps: Optional[Dict] = {} if collect else None
    logits = C.lm_head(params, x, cfg, qcfg, head_sc, head_taps, n_skip)
    if collect:
        taps = {"layers": layer_taps, **(head_taps or {}),
                "final_in": Q.site_stats(x, n_skip)}
    else:
        taps = {}
    return logits, taps


# ---------------------------------------------------------------------------
# Serving: prefill / decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=None, kv_dtype=None, prefix_len: int = 0,
               per_slot_scales: bool = False) -> Params:
    """kv_dtype None -> fp cache {"k","v"}. kv_dtype "int8" -> quantized
    cache: int8 k/v storage (halves decode HBM traffic) + per-(layer,head)
    dequant scales + a full-precision cushion block kc/vc of `prefix_len`
    rows — the sink/pivot-token KV stays intact (KVSink/IntactKV) while the
    int8 tensors hold content positions [prefix_len:max_seq).

    per_slot_scales gives every batch row its own (layer, head) scales —
    shape (L, batch, K) — for the continuous-batching pool, where slots
    admitted at different times each calibrate scales from their own
    admission prefill."""
    dt = dtype or C.dtype_of(cfg)
    K, hd, L = cfg.n_kv_heads, cfg.head_dim, cfg.n_layers
    if kv_dtype is None:
        return {"k": jnp.zeros((L, batch, max_seq, K, hd), dt),
                "v": jnp.zeros((L, batch, max_seq, K, hd), dt)}
    if kv_dtype not in ("int8", jnp.int8):
        raise ValueError(f"unsupported kv_dtype {kv_dtype!r}")
    sshape = (L, batch, K) if per_slot_scales else (L, K)
    return {"k": jnp.zeros((L, batch, max_seq, K, hd), jnp.int8),
            "v": jnp.zeros((L, batch, max_seq, K, hd), jnp.int8),
            "k_scale": jnp.ones(sshape, jnp.float32),
            "v_scale": jnp.ones(sshape, jnp.float32),
            "kc": jnp.zeros((L, prefix_len, K, hd), dt),
            "vc": jnp.zeros((L, prefix_len, K, hd), dt)}


def cache_roles(cfg: ModelConfig, kv_dtype=None,
                per_slot_scales: bool = False) -> Params:
    """KV-cache sharding roles: (L, B, S, K, hd) — batch on B-axes, the
    KV-heads axis on "M" (tensor parallel). Head sharding makes decode
    attention collective-free: each shard attends its local heads against
    its local KV slice and only the o-projection psums, matching the
    flash-decode per-shard head slicing contract (kernels/ops.py
    ``decode_attention_tp``). When the head count doesn't divide the tp
    width the role resolver falls back to replicated for that leaf
    (sharding.roles_pspec). int8 scales shard with their (L, K) heads axis;
    the fp cushion block kc/vc stays REPLICATED — every shard holds the
    full sink block bit-identically (KVSink/IntactKV: the protected prefix
    must survive sharding exactly; consumers slice it per shard on entry)."""
    kv = (None, "B", None, "M", None)
    roles = {"k": kv, "v": kv}
    if kv_dtype is not None:
        sc = (None, "B", "M") if per_slot_scales else (None, "M")
        roles.update({"k_scale": sc, "v_scale": sc, "kc": (), "vc": ()})
    return roles


def write_cushion_to_cache(cache: Params, cushion: Optional[Params]) -> Tuple[Params, int]:
    if cushion is None:
        return cache, 0
    kv = cushion["kv"]
    m = kv["k"].shape[1]
    if "kc" in cache:
        # quantized cache: the cushion block is protected — stored fp,
        # never quantized (init_cache must have been given prefix_len == m)
        assert cache["kc"].shape[1] == m, \
            f"cache prefix_len {cache['kc'].shape[1]} != cushion len {m}"
        cache = dict(cache)
        cache["kc"] = kv["k"].astype(cache["kc"].dtype)
        cache["vc"] = kv["v"].astype(cache["vc"].dtype)
        return cache, m
    k = jnp.broadcast_to(kv["k"][:, None], (kv["k"].shape[0], cache["k"].shape[1]) + kv["k"].shape[1:])
    v = jnp.broadcast_to(kv["v"][:, None], (kv["v"].shape[0], cache["v"].shape[1]) + kv["v"].shape[1:])
    cache = {
        "k": jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0, 0)),
    }
    return cache, m


def write_prompt_kv(cache: Params, ks: Array, vs: Array, m: int) -> Params:
    """Write prefill KV (stacked (L,B,S,K,hd) fp) into the cache at absolute
    positions [m:m+S]. For int8 caches this also derives the static
    per-(layer,head) dequant scales from the prompt KV — decode steps reuse
    them (new tokens are clipped into the calibrated range). A cache with
    per-slot scale leaves ((L,B,K); continuous-batching admission rows)
    calibrates each batch row's scales from its own prompt instead."""
    if "k_scale" in cache:
        if cache["k_scale"].ndim == 3:      # per-slot (L, B, K)
            per_row = jax.vmap(jax.vmap(C.kv_scales_from))
            k_scale = per_row(ks)
            v_scale = per_row(vs)
            kq = jax.vmap(jax.vmap(C.quantize_kv))(ks, k_scale)
            vq = jax.vmap(jax.vmap(C.quantize_kv))(vs, v_scale)
        else:
            k_scale = jax.vmap(C.kv_scales_from)(ks)    # (L, K)
            v_scale = jax.vmap(C.kv_scales_from)(vs)
            kq = jax.vmap(C.quantize_kv)(ks, k_scale)
            vq = jax.vmap(C.quantize_kv)(vs, v_scale)
        cache = dict(cache)
        cache["k"] = jax.lax.dynamic_update_slice(cache["k"], kq,
                                                  (0, 0, m, 0, 0))
        cache["v"] = jax.lax.dynamic_update_slice(cache["v"], vq,
                                                  (0, 0, m, 0, 0))
        cache["k_scale"], cache["v_scale"] = k_scale, v_scale
        return cache
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], ks.astype(cache["k"].dtype), (0, 0, m, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], vs.astype(cache["v"].dtype), (0, 0, m, 0, 0))
    return cache


def finalize_staged_kv(row: Params, cache: Params, cushion: Optional[Params],
                       S: int) -> Params:
    """Rebuild the admission row a *blocking* prefill would have produced
    from a chunk-staged fp row: slice the prompt KV [m:m+S) back out of the
    staging row and write it through the normal write_prompt_kv path, so an
    int8 cache calibrates its per-slot dequant scales from the WHOLE prompt
    (not per chunk — bit-identical to blocking admission) and the protected
    fp cushion block lands in kc/vc untouched."""
    cache, m = write_cushion_to_cache(cache, cushion)
    ks = jax.lax.slice_in_dim(row["k"], m, m + S, axis=2)
    vs = jax.lax.slice_in_dim(row["v"], m, m + S, axis=2)
    return write_prompt_kv(cache, ks, vs, m)


def prefill(params: Params, tokens: Array, cache: Params, cfg: ModelConfig,
            qcfg: QuantConfig, *, scales: Optional[Params] = None,
            cushion: Optional[Params] = None,
            prepend_embeds: Optional[Array] = None,
            remat: bool = False,
            pos_offset: Optional[int] = None) -> Tuple[Array, Params, Array]:
    """Process the prompt, fill the KV cache (cushion at [0:m], prompt at
    [m:m+S]). Returns (last-position logits, cache, next_pos).

    pos_offset (static int) resumes a chunked prefill: positions [0:pos_offset)
    of the B=1 fp cache row already hold the cushion plus every earlier chunk
    (written by a previous prefill call on the same row), and are read back as
    the fully-visible prefix for this chunk's tokens. The cushion must NOT be
    re-attached (chunk 0 only), and the row must be fp — int8 admission rows
    are rebuilt from the finished staging row by finalize_staged_kv so the
    per-slot scales still calibrate over the whole prompt."""
    x = C.embed_tokens(params, tokens, cfg)
    if prepend_embeds is not None:
        x = jnp.concatenate([prepend_embeds.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    if pos_offset is not None:
        if cushion is not None:
            raise ValueError("chunk-resume prefill attaches the cushion on "
                             "chunk 0 only (pos_offset excludes cushion)")
        if "k_scale" in cache:
            raise ValueError("chunk-resume prefill needs an fp staging row")
        if cache["k"].shape[1] != 1:
            raise ValueError("chunk-resume prefill is B=1 only")
        m = int(pos_offset)
        pre = {"k": jax.lax.slice_in_dim(cache["k"], 0, m, axis=2)[:, 0],
               "v": jax.lax.slice_in_dim(cache["v"], 0, m, axis=2)[:, 0]}
    else:
        cache, m = write_cushion_to_cache(cache, cushion)
        pre = cushion["kv"] if cushion is not None else {
            "k": jnp.zeros((cfg.n_layers, 0, cfg.n_kv_heads, cfg.head_dim),
                           x.dtype),
            "v": jnp.zeros((cfg.n_layers, 0, cfg.n_kv_heads, cfg.head_dim),
                           x.dtype)}
    positions = m + jnp.arange(S)

    lscales = C.resolve_scales(scales, SITES, cfg.n_layers, qcfg)

    def body(h, xs):
        lp, lsc, lpre = xs
        hn = C.apply_norm(lp["ln1"], h, cfg)
        a, kv = C.attention_full(lp["attn"], hn, cfg, qcfg, lsc, None,
                                 positions, prefix_kv=lpre, causal=True,
                                 return_kv=True)
        h = h + a
        hn = C.apply_norm(lp["ln2"], h, cfg)
        h = h + C.apply_mlp(lp["mlp"], hn, cfg, qcfg, lsc, None)
        h = constrain(h, "B")
        return h, kv

    if remat:
        body = jax.checkpoint(body)
    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], lscales, pre))
    # ks: (L, B, S, K, hd) -> write into cache at [m : m+S] (int8 caches
    # also calibrate their per-(layer,head) scales here)
    cache = write_prompt_kv(cache, ks, vs, m)
    x = C.apply_norm(params["ln_f"], x, cfg)
    logits = C.lm_head(params, x[:, -1:], cfg, qcfg,
                       scales if scales is not None else None, None)
    return logits, cache, jnp.asarray(m + S, jnp.int32)


def decode_step(params: Params, token: Array, pos: Array, cache: Params,
                cfg: ModelConfig, qcfg: QuantConfig, *,
                scales: Optional[Params] = None) -> Tuple[Array, Params]:
    """One decode step. token: (B,) int32; pos: () int32 shared absolute
    position, or (B,) int32 per-row positions (cushion occupies [0:m),
    prompt/generated next). Per-row pos serves the continuous-batching
    scheduler: each cache slot decodes at its own offset, with RoPE, cache
    writes and attention masking all per-row (see attention_decode_kv)."""
    x = C.embed_tokens(params, token[:, None], cfg)
    lscales = C.resolve_scales(scales, SITES, cfg.n_layers, qcfg)

    def body(h, xs):
        lp, lsc, kvc = xs
        hn = C.apply_norm(lp["ln1"], h, cfg)
        a, kvc = C.attention_decode_kv(lp["attn"], hn, kvc, pos, cfg, qcfg,
                                       lsc, None)
        h = h + a
        hn = C.apply_norm(lp["ln2"], h, cfg)
        h = h + C.apply_mlp(lp["mlp"], hn, cfg, qcfg, lsc, None)
        return h, kvc

    # the cache dict scans layer-wise: every leaf is stacked over L
    x, cache = jax.lax.scan(body, x, (params["layers"], lscales, cache))
    x = C.apply_norm(params["ln_f"], x, cfg)
    logits = C.lm_head(params, x, cfg, qcfg,
                       scales if scales is not None else None, None)
    return logits[:, 0], cache


# ---------------------------------------------------------------------------
# Cushion KV parameter shape (for prefix tuning)
# ---------------------------------------------------------------------------

def cushion_zeros(cfg: ModelConfig, m: int, dtype=None) -> Params:
    # default to the model compute dtype: the artifact must match what
    # extract_cushion emits so serving's bit-identical cushion-rewrite
    # guarantee holds (a bf16 model keeps a bf16 cushion)
    dtype = C.dtype_of(cfg) if dtype is None else dtype
    K, hd, L = cfg.n_kv_heads, cfg.head_dim, cfg.n_layers
    return {"kv": {"k": jnp.zeros((L, m, K, hd), dtype),
                   "v": jnp.zeros((L, m, K, hd), dtype)}}


def loss_fn(params: Params, tokens: Array, labels: Array, cfg: ModelConfig,
            qcfg: QuantConfig, *, scales=None, cushion=None,
            collect: bool = False, n_skip: int = 0, remat: bool = True,
            lam: float = 0.0):
    """Next-token CE (+ optional λ·L_q when collecting)."""
    logits, taps = forward(params, tokens, cfg, qcfg, scales=scales,
                           cushion=cushion, collect=collect or lam > 0,
                           n_skip=n_skip, remat=remat)
    if n_skip:
        # loss on the token part only (prefix positions excluded)
        logits = logits[:, n_skip:]
        labels = labels[:, n_skip:]
    ce = C.cross_entropy(logits, labels)
    loss = ce
    aux = {"ce": ce, "taps": taps}
    if lam > 0 or collect:
        qerr = total_qerr(taps)
        aux["qerr"] = qerr
        if lam > 0:
            loss = loss + lam * qerr
    return loss, aux


def total_qerr(taps: Dict) -> Array:
    """Sum of L_q over all sites and layers (paper eq. 6, summed over
    blocks)."""
    leaves = []

    def visit(d):
        if isinstance(d, dict):
            if "qerr" in d:
                leaves.append(jnp.sum(d["qerr"]))
            else:
                for v in d.values():
                    visit(v)
    visit(taps)
    if not leaves:
        return jnp.zeros((), jnp.float32)
    return functools.reduce(jnp.add, leaves)


def placeholder_all_scales(cfg: ModelConfig) -> Params:
    """Full placeholder scales tree (incl. head) for quantized lowering
    without a calibration artifact (dry-runs)."""
    sc = C.placeholder_scales(SITES, cfg.n_layers)
    sc["head"] = Q.SiteScale(scale=jnp.ones(()), zero=jnp.zeros(()))
    return sc
