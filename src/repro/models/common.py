"""Shared model components: norms, RoPE, quantized linear with activation
taps, GQA attention (full-sequence & single-token-decode, cushion-prefix
aware), MLPs.

Conventions
-----------
* params are nested dicts of arrays; stacked over layers for lax.scan.
* every linear runs through `qlinear`, which applies the configured
  activation/weight quantizer and (optionally) records activation taps
  (quant error L_q + order statistics) for calibration / search / analysis.
* `scales` is a pytree mirroring the taps structure holding `SiteScale`
  leaves for pt_static deployment; placeholder (ignored) otherwise.
* the cushion prefix enters attention as per-layer KV (`prefix_kv`:
  dict(k=(m, K, hd), v=(m, K, hd))), fully visible to every query —
  exactly "inserted as a prefix KV cache" (paper eq. 8).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, QuantConfig
from repro.core import quantization as Q
from repro.distributed.sharding import constrain

Array = jax.Array
Params = Dict[str, Any]


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 1.0) -> Array:
    std = scale / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_init(cfg: ModelConfig, d: Optional[int] = None) -> Params:
    d = d or cfg.d_model
    p = {"g": jnp.ones((d,), dtype_of(cfg))}
    if cfg.norm == "layernorm":
        p["b"] = jnp.zeros((d,), dtype_of(cfg))
    return p


def apply_norm(p: Params, x: Array, cfg: ModelConfig) -> Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
        y = y * p["g"].astype(jnp.float32) + p["b"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + 1e-6) * p["g"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (half-rotation / llama convention)
# ---------------------------------------------------------------------------

def rope_cos_sin(positions: Array, d_head: int, theta: float
                 ) -> Tuple[Array, Array]:
    """positions: (...,) -> cos/sin (..., d_head//2), fp32."""
    inv = 1.0 / (theta ** (np.arange(0, d_head, 2) / d_head))
    ang = positions.astype(jnp.float32)[..., None] * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: Array, cos: Array, sin: Array) -> Array:
    """x: (..., n_heads, d_head); cos/sin broadcast over the head axis."""
    xf = x.astype(jnp.float32)
    x1, x2 = jnp.split(xf, 2, axis=-1)
    cos = cos[..., None, :]
    sin = sin[..., None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Quantized linear with taps
# ---------------------------------------------------------------------------

def get_site(scales: Optional[Params], name: str) -> Optional[Q.SiteScale]:
    if scales is None:
        return None
    return scales.get(name)


def qlinear(x: Array, w: Array, b: Optional[Array], qcfg: QuantConfig,
            scales: Optional[Params], site: str, taps: Optional[Dict],
            n_skip: int = 0) -> Array:
    """y = q(x) @ q(w) + b, recording taps for `site` when collecting."""
    if taps is not None:
        taps[site] = {
            "qerr": Q.site_qerr(x, qcfg, get_site(scales, site), n_skip),
            **Q.site_stats(x, n_skip),
        }
    y = Q.qdot(x, w, qcfg, get_site(scales, site))
    if b is not None:
        y = y + b
    return y


def placeholder_scales(sites: Tuple[str, ...], n_layers: int) -> Params:
    """Stacked (L,)-leaf SiteScale tree (used when no calibration is loaded;
    values are ignored unless qcfg.mode == 'pt_static')."""
    one = lambda: Q.SiteScale(scale=jnp.ones((n_layers,), jnp.float32),
                              zero=jnp.zeros((n_layers,), jnp.float32))
    return {s: one() for s in sites}


def resolve_scales(scales: Optional[Params], sites: Tuple[str, ...],
                   n_layers: int, qcfg: QuantConfig) -> Params:
    """Per-layer scales tree for a forward: the calibrated tree when given,
    else placeholders. Refuses ``pt_static`` with no calibrated scales —
    the placeholder (scale=1, zero=0) tree would silently clip every
    activation to [0, 255] and produce garbage logits, which is exactly the
    failure mode a served model must never hit. Callers that only need a
    quantized *lowering* (dry-runs) pass ``placeholder_all_scales``
    explicitly and bypass this guard."""
    if scales is not None:
        return {s: scales[s] for s in sites}
    if qcfg.mode == "pt_static":
        raise ValueError(
            "pt_static forward without calibrated scales: per-tensor static "
            "quantization needs site scales from core.calibration.calibrate "
            "(serve.py runs it at engine load via --calib-batches); refusing "
            "to run on placeholder scales, which would produce wrong logits "
            "silently")
    return placeholder_scales(sites, n_layers)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

ATTN_SITES = ("qkv", "o")


def attn_init(key, cfg: ModelConfig) -> Params:
    hd, H, K = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    k1, k2 = jax.random.split(key)
    dt = dtype_of(cfg)
    p = {
        "wqkv": dense_init(k1, cfg.d_model, (H + 2 * K) * hd, dt),
        "wo": dense_init(k2, H * hd, cfg.d_model, dt,
                         scale=1.0 / np.sqrt(2 * cfg.n_layers)),
    }
    if cfg.qkv_bias:
        p["bqkv"] = jnp.zeros(((H + 2 * K) * hd,), dt)
    return p


def _split_qkv(qkv: Array, cfg: ModelConfig) -> Tuple[Array, Array, Array]:
    hd, H, K = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    q, k, v = jnp.split(qkv, [H * hd, (H + K) * hd], axis=-1)
    q = q.reshape(*q.shape[:-1], H, hd)
    k = k.reshape(*k.shape[:-1], K, hd)
    v = v.reshape(*v.shape[:-1], K, hd)
    return q, k, v


FLASH_THRESHOLD = 4096 * 4096   # S*T above this -> chunked online softmax
FLASH_Q_CHUNK = 512
FLASH_KV_CHUNK = 1024


def _sdpa_dense(q: Array, k: Array, v: Array, mask: Optional[Array],
                cfg: ModelConfig) -> Array:
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    q = q.reshape(B, S, K, G, hd)
    if S > 1:
        q = constrain(q, "B", None, "M")
    logits = jnp.einsum("bskgh,btkh->bkgst", q, k,
                        preferred_element_type=jnp.float32)
    # heads (kv-head axis) on "M" at prefill AND decode, matching the
    # serve-pool layout (models/*.cache_roles): per-head attention is
    # shard-local, softmax over T needs no collective, and only the
    # o-projection psums. Sharding the KV-seq axis instead (split-KV)
    # would force a per-layer reshard of the heads-sharded cache.
    logits = constrain(logits, "B", "M")
    logits = logits / np.sqrt(hd)
    if mask is not None:
        if mask.ndim == 3:
            m = mask[:, None, None, :, :]
        else:
            m = mask[None, None, None, :, :]
        logits = jnp.where(m, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v)
    return out.reshape(B, S, H, hd)


def flash_attention_jnp(q: Array, k: Array, v: Array, cfg: ModelConfig,
                        causal: bool, prefix_len: int = 0,
                        q_chunk: int = FLASH_Q_CHUNK,
                        kv_chunk: int = FLASH_KV_CHUNK,
                        prefix_valid: Optional[Array] = None) -> Array:
    """Chunked online-softmax attention (pure jnp; memory O(chunk^2) instead
    of O(S*T)). Also the oracle for the Pallas flash kernel.

    q: (B,S,H,hd); k/v: (B,T,K,hd) where T = prefix_len + S for causal
    self-attention with a cushion prefix (prefix positions fully visible).
    prefix_valid: optional (prefix_len,) bool — live-length mask for a
    *padded* prefix (the compile-once search path); False rows are invisible
    to every query.
    """
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, T)
    nq = -(-S // q_chunk)
    nk = -(-T // kv_chunk)
    Sp, Tp = nq * q_chunk, nk * kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    qh = qp.reshape(B, nq, q_chunk, K, G, hd)
    kh = kp.reshape(B, nk, kv_chunk, K, hd)
    vh = vp.reshape(B, nk, kv_chunk, K, hd)
    scale = 1.0 / np.sqrt(hd)
    kv_ok = None
    if prefix_valid is not None:
        kv_ok = jnp.pad(jnp.concatenate(
            [prefix_valid, jnp.ones((T - prefix_len,), bool)]), (0, Tp - T))

    def q_block(qi, qc):
        # qc: (B, q_chunk, K, G, hd); online softmax over kv chunks
        acc0 = jnp.zeros((B, q_chunk, K, G, hd), jnp.float32)
        m0 = jnp.full((B, K, G, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, K, G, q_chunk), jnp.float32)

        def kv_block(carry, ki):
            acc, m, l = carry
            kc = kh[:, ki]
            vc = vh[:, ki]
            s = jnp.einsum("bskgh,btkh->bkgst", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            iq = qi * q_chunk + jnp.arange(q_chunk)
            jk = ki * kv_chunk + jnp.arange(kv_chunk)
            valid = (jk < T)[None, :]
            if kv_ok is not None:
                valid = valid & kv_ok[jk][None, :]
            if causal:
                vis = (jk[None, :] < prefix_len) | \
                      (jk[None, :] <= iq[:, None] + prefix_len)
                valid = valid & vis
            s = jnp.where(valid[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # guard fully-masked rows
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(valid[None, None, None], p, 0.0)
            alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l = l * alpha + jnp.sum(p, axis=-1)
            acc = acc * jnp.transpose(alpha, (0, 3, 1, 2))[..., None] \
                + jnp.einsum("bkgst,btkh->bskgh", p, vc.astype(jnp.float32))
            return (acc, m_new, l), ()

        (acc, m, l), _ = jax.lax.scan(kv_block, (acc0, m0, l0),
                                      jnp.arange(nk))
        lT = jnp.transpose(l, (0, 3, 1, 2))[..., None]
        return acc / jnp.maximum(lT, 1e-30)

    out = jax.lax.map(lambda i: q_block(i, qh[:, i]), jnp.arange(nq))
    out = jnp.transpose(out, (1, 0, 2, 3, 4, 5)) \
        .reshape(B, Sp, K * G * hd)[:, :S]
    return out.reshape(B, S, H, hd).astype(v.dtype)


def _sdpa(q: Array, k: Array, v: Array, mask: Optional[Array],
          cfg: ModelConfig) -> Array:
    """q: (B,S,H,hd); k/v: (B,T,K,hd); mask: (S,T) or (B,S,T) bool or None.
    GQA: H = K * G. Returns (B,S,H,hd). Dispatches to the chunked flash
    path for large S*T (the mask is then re-derived from causal+prefix
    structure by the callers that need it)."""
    return _sdpa_dense(q, k, v, mask, cfg)


def attention_full(p: Params, x: Array, cfg: ModelConfig, qcfg: QuantConfig,
                   scales: Optional[Params], taps: Optional[Dict],
                   positions: Array,
                   prefix_kv: Optional[Params] = None,
                   causal: bool = True,
                   n_skip: int = 0,
                   return_kv: bool = False,
                   prefix_valid: Optional[Array] = None):
    """Full-sequence attention (train / prefill).

    positions: (S,) absolute positions of x's tokens (already offset past the
    cushion prefix). prefix_kv: dict(k,v) of shape (m, K, hd) — the
    CushionCache for this layer; fully visible to all queries.
    prefix_valid: optional (m,) bool live-length mask for a prefix_kv padded
    to a fixed shape (the compile-once greedy-search scoring path): rows
    where it is False are masked out of every query's visibility.
    """
    B, S, _ = x.shape
    qkv = qlinear(x, p["wqkv"], p.get("bqkv"), qcfg, scales, "qkv", taps,
                  n_skip)
    q, k, v = _split_qkv(qkv, cfg)
    cos, sin = rope_cos_sin(positions, cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    k = constrain(k, "B", None, "M")
    v = constrain(v, "B", None, "M")
    new_kv = (k, v)

    m = 0
    if prefix_kv is not None:
        m = prefix_kv["k"].shape[0]
        pk = jnp.broadcast_to(prefix_kv["k"][None], (B, m) + prefix_kv["k"].shape[1:])
        pv = jnp.broadcast_to(prefix_kv["v"][None], (B, m) + prefix_kv["v"].shape[1:])
        k = jnp.concatenate([pk.astype(k.dtype), k], axis=1)
        v = jnp.concatenate([pv.astype(v.dtype), v], axis=1)

    T = k.shape[1]
    if S * T >= FLASH_THRESHOLD:
        out = flash_attention_jnp(q, k, v, cfg, causal=causal, prefix_len=m,
                                  prefix_valid=prefix_valid)
    else:
        if causal:
            i = jnp.arange(S)[:, None]
            j = jnp.arange(m + S)[None, :]
            mask = j < (i + m + 1)      # prefix (j<m) always visible
            if prefix_valid is not None:
                kv_ok = jnp.concatenate([prefix_valid, jnp.ones((S,), bool)])
                mask = mask & kv_ok[None, :]
        elif prefix_valid is not None:
            kv_ok = jnp.concatenate([prefix_valid, jnp.ones((S,), bool)])
            mask = jnp.broadcast_to(kv_ok[None, :], (S, m + S))
        else:
            mask = None
        out = _sdpa(q, k, v, mask, cfg)
    out = out.reshape(B, S, cfg.n_heads * cfg.head_dim)
    y = qlinear(out, p["wo"], None, qcfg, scales, "o", taps, n_skip)
    if return_kv:
        return y, new_kv
    return y


def _use_decode_kernel() -> bool:
    """Route decode attention through the Pallas split-KV kernel? "auto"
    enables it on TPU backends only (the jnp path is the CPU oracle)."""
    from repro.flags import DECODE_KERNEL
    if DECODE_KERNEL == "pallas":
        return True
    if DECODE_KERNEL == "jnp":
        return False
    return jax.default_backend() == "tpu"


def quantize_kv(x: Array, scale: Array) -> Array:
    """Symmetric per-head int8 KV quantization (the core quantizer with a
    per-head scale). x: (..., K, hd); scale: (K,) fp32 — or per-row (B, K)
    against x (B, S, K, hd) (continuous batching: every cache slot carries
    the scales its own admission prefill calibrated)."""
    if scale.ndim == 2 and x.ndim == 4:
        scale = scale[:, None, :, None]          # (B,K) -> (B,1,K,1)
    else:
        scale = scale[..., :, None]
    q = Q.quantize(x.astype(jnp.float32), scale,
                   jnp.zeros(()), bits=8, symmetric=True)
    return q.astype(jnp.int8)


def kv_scales_from(k: Array, head_axis: int = -2) -> Array:
    """Per-kv-head static dequant scale from observed KV (symmetric amax
    rule from the quantization core, with a floor). Reduces over every axis
    except `head_axis`."""
    axes = tuple(a for a in range(k.ndim) if a != head_axis % k.ndim)
    amax = jnp.max(jnp.abs(k.astype(jnp.float32)), axis=axes)
    scale, _ = Q.params_from_minmax(-amax, amax, bits=8, symmetric=True)
    return jnp.maximum(scale, 1e-6)


def attention_decode_kv(p: Params, x: Array, kv: Params, pos: Array,
                        cfg: ModelConfig, qcfg: QuantConfig,
                        scales: Optional[Params], taps: Optional[Dict]
                        ) -> Tuple[Array, Params]:
    """Single-token decode over one layer's KV-cache dict (the serving fast
    path). x: (B,1,D); pos: () shared absolute write position, or (B,)
    per-row positions (continuous batching: every cache slot carries its own
    decode position — RoPE, the cache write and the attention mask are all
    per-row). Rows must keep pos within [0, Smax): the scheduler freezes a
    retired slot's pos at its last value (>= cushion length) so its dummy
    writes keep landing on its own scratch position and never touch the
    cushion block; its masked output is discarded.

    kv is either the fp cache {"k","v": (B,Smax,K,hd)} (cushion rows live
    in-cache at [0:m)) or the int8 cache
        {"k","v": int8 (B,Smax,K,hd), "k_scale","v_scale": (K,) fp32,
         "kc","vc": (m,K,hd) fp}
    where the cushion/sink block is kept intact in fp (KVSink/IntactKV rule)
    and the int8 tensors hold content positions [m:Smax) only. The new
    token's KV is quantized with the static per-(layer,head) scales derived
    at prefill; per-slot scales (B, K) are accepted too (the continuous
    pool calibrates each slot's scales at its own admission prefill —
    quantization, dequant and the kernel read are then all per-row).

    A third layout is the PAGED pool (serving/paging.py): kv carries
    "page_table" (B, P) int32 and k/v become a flat (n_pages, ps, K, hd)
    page store shared by all rows; logical positions are unchanged
    (pos//ps selects the logical page, the table the physical one) and the
    shared fp cushion rides in batch-free kc/vc refs for BOTH fp and int8
    pools. Writes scatter into the mapped page; reads route through
    flash_decode_paged (TPU) or a gather + the contiguous CPU paths.

    Attention runs on the Pallas split-KV flash-decode kernel on TPU, or
    the jnp oracle elsewhere. Returns (y, updated kv dict).
    """
    B = x.shape[0]
    qkv = qlinear(x, p["wqkv"], p.get("bqkv"), qcfg, scales, "qkv", taps)
    q, k, v = _split_qkv(qkv, cfg)
    per_row = jnp.ndim(pos) == 1
    posv = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))
    cos, sin = rope_cos_sin(posv[:, None], cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)         # cos/sin: (B, 1, hd/2)
    k = apply_rope(k, cos, sin)

    quantized = "k_scale" in kv
    paged = "page_table" in kv
    if quantized:
        ks, vs = kv["k_scale"], kv["v_scale"]
        k_wr = quantize_kv(k, ks)
        v_wr = quantize_kv(v, vs)
    else:
        k_wr = k.astype(kv["k"].dtype)
        v_wr = v.astype(kv["v"].dtype)
    if paged:
        # paged pool (serving/paging.py): k/v are a flat (n_pages,ps,K,hd)
        # page store and page_table (B,P) maps row b's logical page
        # posv//ps to a physical page. Retired rows keep a frozen pos AND a
        # zeroed table row, so their dummy writes land on the reserved
        # scratch page 0 — never on a page the allocator may have recycled.
        pt = kv["page_table"]
        ps = kv["k"].shape[1]
        wpos = jnp.maximum(posv, 0)     # no negative page/offset wraps
        phys = pt[jnp.arange(B), wpos // ps]
        cache_k = kv["k"].at[phys, wpos % ps].set(k_wr[:, 0])
        cache_v = kv["v"].at[phys, wpos % ps].set(v_wr[:, 0])
        cache_k = constrain(cache_k, None, None, "M")
        cache_v = constrain(cache_v, None, None, "M")
    elif per_row:
        # each row writes at its own position (vmapped update -> scatter)
        row_wr = jax.vmap(
            lambda c, u, p_: jax.lax.dynamic_update_slice(c, u, (p_, 0, 0)))
        cache_k = row_wr(kv["k"], k_wr, posv)
        cache_v = row_wr(kv["v"], v_wr, posv)
    else:
        cache_k = jax.lax.dynamic_update_slice(kv["k"], k_wr, (0, pos, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(kv["v"], v_wr, (0, pos, 0, 0))
    if not paged:
        # keep the written cache in the serve-pool layout (heads on "M") so
        # the per-step update is a shard-local update, never a reshard
        cache_k = constrain(cache_k, "B", None, "M")
        cache_v = constrain(cache_v, "B", None, "M")
    new = dict(kv)
    new["k"], new["v"] = cache_k, cache_v

    q1 = q[:, 0]                        # (B, H, hd)
    if _use_decode_kernel():
        from repro.distributed.sharding import active_mesh
        from repro.kernels.ops import (decode_attention_paged,
                                       decode_attention_pallas,
                                       decode_attention_tp,
                                       decode_attention_tp_paged)
        mesh = active_mesh()
        tp = (mesh.shape["tp"] if mesh is not None
              and "tp" in mesh.axis_names else 1)
        interpret = jax.default_backend() != "tpu"
        if tp > 1 and cfg.n_kv_heads % tp == 0:
            # shard_map the kernel over the tp axis: each shard runs
            # flash-decode on its local head slice (local q heads, local KV
            # heads, local int8 scales; the replicated cushion block is
            # sliced per shard on entry) — no collectives inside attention
            if paged:
                out = decode_attention_tp_paged(
                    q1, cache_k, cache_v, kv["page_table"], posv, mesh,
                    k_scale=ks if quantized else None,
                    v_scale=vs if quantized else None,
                    kc=kv.get("kc"), vc=kv.get("vc"), interpret=interpret)
            else:
                out = decode_attention_tp(
                    q1, cache_k, cache_v, posv, mesh,
                    k_scale=ks if quantized else None,
                    v_scale=vs if quantized else None,
                    kc=kv.get("kc"), vc=kv.get("vc"), interpret=interpret)
        elif paged:
            out = decode_attention_paged(
                q1, cache_k, cache_v, kv["page_table"], posv,
                k_scale=ks if quantized else None,
                v_scale=vs if quantized else None,
                kc=kv.get("kc"), vc=kv.get("vc"), interpret=interpret)
        else:
            out = decode_attention_pallas(
                q1, cache_k, cache_v, posv,
                k_scale=ks if quantized else None,
                v_scale=vs if quantized else None,
                kc=kv.get("kc"), vc=kv.get("vc"), interpret=interpret)
    elif paged:
        # jnp fallback for paged pools: gather the page table into the
        # dense layout and reuse the contiguous CPU paths verbatim — the
        # gathered values equal the contiguous pool's at every visible
        # position and the masked tail underflows to exactly zero weight,
        # so paged-vs-contiguous tokens stay bit-identical on CPU too.
        from repro.kernels.ref import flash_decode_ref, gather_pages
        kd = gather_pages(cache_k, kv["page_table"])
        vd = gather_pages(cache_v, kv["page_table"])
        if quantized:
            out = flash_decode_ref(q1, kd, vd, posv, k_scale=ks, v_scale=vs,
                                   kc=kv.get("kc"), vc=kv.get("vc"))
        else:
            mc = 0 if "kc" not in kv else kv["kc"].shape[0]
            if mc:
                # splice the shared fp cushion over the scratch-mapped
                # positions [0:m) so the dense math matches the contiguous
                # fp pool (which holds the cushion in-cache) bit-for-bit
                kcb = jnp.broadcast_to(kv["kc"].astype(kd.dtype)[None],
                                       (B,) + kv["kc"].shape)
                vcb = jnp.broadcast_to(kv["vc"].astype(vd.dtype)[None],
                                       (B,) + kv["vc"].shape)
                kd = jnp.concatenate([kcb, kd[:, mc:]], axis=1)
                vd = jnp.concatenate([vcb, vd[:, mc:]], axis=1)
            Smax = kd.shape[1]
            mask = jnp.arange(Smax)[None, :] <= posv[:, None]
            out = _sdpa(q, kd, vd, mask[:, None, :], cfg)[:, 0]
            out = jnp.where((posv >= 0)[:, None, None], out,
                            0.0).astype(out.dtype)
    elif quantized:
        from repro.kernels.ref import flash_decode_ref
        out = flash_decode_ref(q1, cache_k, cache_v, posv, k_scale=ks,
                               v_scale=vs, kc=kv.get("kc"), vc=kv.get("vc"))
    else:
        Smax = cache_k.shape[1]
        mask = jnp.arange(Smax)[None, :] <= posv[:, None]   # (B, Smax)
        out = _sdpa(q, cache_k, cache_v, mask[:, None, :], cfg)[:, 0]
        # retired rows (pos < 0, nothing visible): zeros, matching the
        # kernel and flash_decode_ref instead of softmax's uniform average
        out = jnp.where((posv >= 0)[:, None, None], out, 0.0).astype(out.dtype)
    out = out.reshape(B, 1, cfg.n_heads * cfg.head_dim)
    y = qlinear(out, p["wo"], None, qcfg, scales, "o", taps)
    return y, new


def attention_decode(p: Params, x: Array, cache_k: Array, cache_v: Array,
                     pos: Array, cfg: ModelConfig, qcfg: QuantConfig,
                     scales: Optional[Params], taps: Optional[Dict]):
    """Single-token decode over bare fp cache arrays (legacy signature;
    encdec's self-attention still uses it). Delegates to
    attention_decode_kv."""
    y, new = attention_decode_kv(p, x, {"k": cache_k, "v": cache_v}, pos,
                                 cfg, qcfg, scales, taps)
    return y, new["k"], new["v"]


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

MLP_SITES = ("mlp_in", "down")


def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d_ff = d_ff or cfg.d_ff
    dt = dtype_of(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_up": dense_init(k1, cfg.d_model, d_ff, dt),
         "w_down": dense_init(k2, d_ff, cfg.d_model, dt,
                              scale=1.0 / np.sqrt(2 * cfg.n_layers))}
    if cfg.gated_mlp:
        p["w_gate"] = dense_init(k3, cfg.d_model, d_ff, dt)
    return p


def apply_mlp(p: Params, x: Array, cfg: ModelConfig, qcfg: QuantConfig,
              scales: Optional[Params], taps: Optional[Dict],
              n_skip: int = 0) -> Array:
    up = qlinear(x, p["w_up"], None, qcfg, scales, "mlp_in", taps, n_skip)
    if cfg.gated_mlp:
        # gate shares the "mlp_in" site (same input tensor -> same scale);
        # taps recorded once on the up projection.
        gate = qlinear(x, p["w_gate"], None, qcfg, scales, "mlp_in", None,
                       n_skip)
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    h = constrain(h, "B", None, "M")
    return qlinear(h, p["w_down"], None, qcfg, scales, "down", taps, n_skip)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embed_init(key, cfg: ModelConfig) -> Params:
    dt = dtype_of(cfg)
    p = {"embed": {"w": (jax.random.normal(key, (cfg.vocab_size, cfg.d_model),
                                           jnp.float32) * 0.02).astype(dt)}}
    if not cfg.tie_embeddings:
        p["head"] = {"w": dense_init(jax.random.fold_in(key, 1), cfg.d_model,
                                     cfg.vocab_size, dt)}
    return p


def embed_tokens(p: Params, tokens: Array, cfg: ModelConfig) -> Array:
    x = jnp.take(p["embed"]["w"], tokens, axis=0)
    return constrain(x, "B")


def lm_head(p: Params, x: Array, cfg: ModelConfig, qcfg: QuantConfig,
            scales: Optional[Params], taps: Optional[Dict],
            n_skip: int = 0) -> Array:
    w = p["embed"]["w"].T if cfg.tie_embeddings else p["head"]["w"]
    site = {"head": scales["head"]} if (scales is not None and "head" in scales) else None
    if taps is not None:
        taps["head"] = {"qerr": Q.site_qerr(x, qcfg, get_site(site, "head"),
                                            n_skip),
                        **Q.site_stats(x, n_skip)}
    logits = Q.qdot(x, w, qcfg, get_site(site, "head"))
    return constrain(logits, "B", None, "M")


def cross_entropy(logits: Array, labels: Array) -> Array:
    """Mean next-token CE; logits (B,S,V) (vocab possibly model-sharded),
    labels (B,S) int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)
