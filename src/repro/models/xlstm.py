"""xLSTM language model: alternating mLSTM (matrix-memory, attention-like
parallel form for training; O(1) recurrent decode) and sLSTM (scalar-memory,
sequential) blocks.

This family has no softmax-attention KV cache, so the paper's prefix-KV
CushionCache does not apply directly. The implemented analogue
("CushionState", see DESIGN.md §5) is a per-layer trainable *initial
recurrent state* optimized with the same L_pred + λ·L_q objective; the greedy
token-prefix search still applies (prefix tokens condition the state).

All recurrences are stabilized in log space (exponential gating with max
state m), matching the xLSTM paper.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, QuantConfig
from repro.core import quantization as Q
from repro.distributed.sharding import constrain
from repro.models import common as C
from repro.models import transformer as T

Array = jax.Array
Params = Dict[str, Any]

SITES = ("m_in", "m_out", "s_in", "s_out")

# Greedy-search scoring fallback: the prefix artifact is recurrent state,
# not attention KV — a fixed-shape padded prefix cannot be masked out of the
# recurrence, so the search falls back to `cushioncache.greedy_search_ref`
# (full forward per candidate, one recompile per appended token).
SUPPORTS_PREFIX_KV_SCORING = False

# Continuous-batching slot layout. The cache is a state *tree* (stacked
# mLSTM/sLSTM states per pair), so the entries are nested per-leaf batch
# axes: every leaf is (P, B, ...) after the pair-vmap — batch on axis 1
# throughout. The recurrence ignores the scheduler's per-row pos vector
# (O(1) state, no positions), and dead pool rows advancing garbage state is
# harmless: admission scatters the full per-request row before the slot is
# read again.
CACHE_BATCH_AXES = {"m": {"C": 1, "n": 1, "m": 1},
                    "s": {"c": 1, "n": 1, "h": 1, "m": 1}}


def dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    inner = cfg.ssm.expand * cfg.d_model if cfg.ssm else 2 * cfg.d_model
    NH = cfg.n_heads
    assert inner % NH == 0
    return inner, NH, inner // NH


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg: ModelConfig) -> Params:
    inner, NH, hd = dims(cfg)
    D = cfg.d_model
    dt = C.dtype_of(cfg)
    ks = jax.random.split(key, 4)
    return {
        "w_qkv": C.dense_init(ks[0], D, 3 * inner, dt),
        "w_if": (jax.random.normal(ks[1], (D, 2 * NH), jnp.float32)
                 / np.sqrt(D)).astype(jnp.float32),
        "b_if": jnp.concatenate([jnp.zeros((NH,)),
                                 jnp.linspace(3.0, 6.0, NH)]).astype(jnp.float32),
        "w_o": C.dense_init(ks[2], D, inner, dt),
        "w_proj": C.dense_init(ks[3], inner, D, dt,
                               scale=1.0 / np.sqrt(2 * cfg.n_layers)),
    }


def mlstm_state(cfg: ModelConfig, batch: int) -> Params:
    _, NH, hd = dims(cfg)
    return {"C": jnp.zeros((batch, NH, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, NH, hd), jnp.float32),
            "m": jnp.full((batch, NH), -1e30, jnp.float32)}


def _mlstm_qkvif(p: Params, x: Array, cfg: ModelConfig, qcfg, scales, taps,
                 n_skip, site="m_in"):
    inner, NH, hd = dims(cfg)
    B, S, _ = x.shape
    qkv = C.qlinear(x, p["w_qkv"], None, qcfg, scales, site, taps, n_skip)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    shp = (B, S, NH, hd)
    q = constrain(q.reshape(shp), "B", None, "M")
    k = constrain(k.reshape(shp), "B", None, "M") / np.sqrt(hd)
    v = constrain(v.reshape(shp), "B", None, "M")
    gif = x.astype(jnp.float32) @ p["w_if"] + p["b_if"]
    li, lf_raw = jnp.split(gif, 2, axis=-1)               # (B,S,NH)
    lf = jax.nn.log_sigmoid(lf_raw)
    og = jax.nn.sigmoid(x @ p["w_o"])                      # (B,S,inner)
    return q, k, v, li, lf, og


def _mlstm_mix(q: Array, k: Array, v: Array, li: Array, lf: Array,
               init_state: Optional[Params], return_state: bool):
    """Stabilized parallel (quadratic-in-S) mLSTM mixing.
    q/k/v: (B,S,NH,hd); li/lf: (B,S,NH). Returns h (B,NH,S,hd) fp32
    (+ final state)."""
    B, S, NH, hd = q.shape
    b = jnp.cumsum(lf, axis=1)                              # (B,S,NH)
    bT = jnp.transpose(b, (0, 2, 1))                        # (B,NH,S)
    liT = jnp.transpose(li, (0, 2, 1))
    # logD[t,s] = b_t - b_s + li_s  (s <= t)
    logD = bT[:, :, :, None] - bT[:, :, None, :] + liT[:, :, None, :]
    tri = jnp.tril(jnp.ones((S, S), bool))
    logD = jnp.where(tri[None, None], logD, -jnp.inf)

    if init_state is not None:
        m0 = init_state["m"]                                # (B,NH)
        C0 = init_state["C"]
        n0 = init_state["n"]
        inter_log = bT + m0[:, :, None]                     # (B,NH,S)
    else:
        inter_log = jnp.full_like(bT, -jnp.inf)

    m_row = jnp.maximum(jnp.max(logD, axis=-1), inter_log)  # (B,NH,S)
    m_row = jnp.maximum(m_row, -1e30)
    Dm = jnp.exp(logD - m_row[..., None])                   # (B,NH,S,S)

    qh = jnp.transpose(q, (0, 2, 1, 3)).astype(jnp.float32)  # (B,NH,S,hd)
    kh = jnp.transpose(k, (0, 2, 1, 3)).astype(jnp.float32)
    vh = jnp.transpose(v, (0, 2, 1, 3)).astype(jnp.float32)
    scores = jnp.einsum("bhtd,bhsd->bhts", qh, kh) * Dm
    num = jnp.einsum("bhts,bhsd->bhtd", scores, vh)
    den = jnp.sum(scores, axis=-1)                           # (B,NH,S)
    if init_state is not None:
        iw = jnp.exp(inter_log - m_row)                      # (B,NH,S)
        num = num + iw[..., None] * jnp.einsum("bhtd,bhde->bhte", qh, C0)
        den = den + iw * jnp.einsum("bhtd,bhd->bht", qh, n0)
    norm = jnp.maximum(jnp.abs(den), jnp.exp(-m_row))
    h = num / norm[..., None]                                # (B,NH,S,hd)

    if not return_state:
        return h
    # final state (stabilized)
    bS = bT[:, :, -1]                                        # (B,NH)
    w_log = bS[:, :, None] - bT + liT                        # (B,NH,S)
    m_state = jnp.max(w_log, axis=-1)
    if init_state is not None:
        m_state = jnp.maximum(m_state, bS + init_state["m"])
    w = jnp.exp(w_log - m_state[..., None])                  # (B,NH,S)
    Cn = jnp.einsum("bhs,bhsd,bhse->bhde", w, kh, vh)
    nn = jnp.einsum("bhs,bhsd->bhd", w, kh)
    if init_state is not None:
        iw0 = jnp.exp(bS + init_state["m"] - m_state)
        Cn = Cn + iw0[..., None, None] * init_state["C"]
        nn = nn + iw0[..., None] * init_state["n"]
    return h, {"C": Cn, "n": nn, "m": m_state}


# chunk length for the chunkwise-parallel form (perf iteration 1, see
# EXPERIMENTS.md §Perf: the full quadratic form materializes O(S^2) decay
# matrices and dominated the HBM roofline term at 32k context)
from repro.flags import MLSTM_CHUNK


def apply_mlstm(p: Params, x: Array, cfg: ModelConfig, qcfg: QuantConfig,
                scales: Optional[Params], taps: Optional[Dict],
                n_skip: int = 0, init_state: Optional[Params] = None,
                return_state: bool = False, chunk: int = MLSTM_CHUNK):
    """mLSTM block: one fused QKV/gate projection over the full sequence,
    then chunkwise-parallel mixing — intra-chunk quadratic (MXU-friendly),
    inter-chunk recurrent state carry (O(S*chunk) memory instead of
    O(S^2))."""
    B, S, D = x.shape
    inner, NH, hd = dims(cfg)
    q, k, v, li, lf, og = _mlstm_qkvif(p, x, cfg, qcfg, scales, taps, n_skip)

    if chunk <= 0 or S <= chunk or S % chunk != 0:
        res = _mlstm_mix(q, k, v, li, lf, init_state, return_state)
        h, state = res if return_state else (res, None)
    else:
        nc = S // chunk
        st0 = init_state if init_state is not None else mlstm_state(cfg, B)
        st0 = jax.tree_util.tree_map(lambda a: a.astype(jnp.float32), st0)

        def body(st, xs):
            qc, kc, vc, lic, lfc = xs
            hc, st2 = _mlstm_mix(qc, kc, vc, lic, lfc, st, True)
            return st2, hc

        split = lambda a: jnp.moveaxis(
            a.reshape(B, nc, chunk, *a.shape[2:]), 1, 0)
        state, hs = jax.lax.scan(
            body, st0, (split(q), split(k), split(v), split(li), split(lf)))
        # hs: (nc, B, NH, chunk, hd) -> (B, NH, S, hd)
        h = jnp.moveaxis(hs, 0, 2).reshape(B, NH, S, hd)

    h = jnp.transpose(h, (0, 2, 1, 3)).reshape(B, S, inner)
    h = (h.astype(x.dtype)) * og.astype(x.dtype)
    h = constrain(h, "B", None, "M")
    out = C.qlinear(h, p["w_proj"], None, qcfg, scales, "m_out", taps, n_skip)
    if return_state:
        return out, state
    return out


def decode_mlstm(p: Params, x: Array, state: Params, cfg: ModelConfig,
                 qcfg: QuantConfig, scales: Optional[Params],
                 taps: Optional[Dict] = None):
    """x: (B,1,D). Sequential stabilized step."""
    B = x.shape[0]
    inner, NH, hd = dims(cfg)
    q, k, v, li, lf, og = _mlstm_qkvif(p, x, cfg, qcfg, scales, taps, 0)
    q, k, v = (a[:, 0].astype(jnp.float32) for a in (q, k, v))  # (B,NH,hd)
    li, lf = li[:, 0], lf[:, 0]                                  # (B,NH)
    m_new = jnp.maximum(lf + state["m"], li)
    fp = jnp.exp(lf + state["m"] - m_new)
    ip = jnp.exp(li - m_new)
    Cn = fp[..., None, None] * state["C"] \
        + ip[..., None, None] * jnp.einsum("bhd,bhe->bhde", k, v)
    nn = fp[..., None] * state["n"] + ip[..., None] * k
    den = jnp.einsum("bhd,bhd->bh", q, nn)
    norm = jnp.maximum(jnp.abs(den), jnp.exp(-m_new))
    h = jnp.einsum("bhd,bhde->bhe", q, Cn) / norm[..., None]
    h = h.reshape(B, 1, inner).astype(x.dtype) * og
    out = C.qlinear(h, p["w_proj"], None, qcfg, scales, "m_out", taps)
    return out, {"C": Cn, "n": nn, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, cfg: ModelConfig) -> Params:
    inner, NH, hd = dims(cfg)
    D = cfg.d_model
    dt = C.dtype_of(cfg)
    ks = jax.random.split(key, 3)
    return {
        "w": C.dense_init(ks[0], D, 4 * inner, dt),
        "r": (jax.random.normal(ks[1], (NH, hd, 4 * hd), jnp.float32)
              / np.sqrt(hd)).astype(jnp.float32),
        "b": jnp.zeros((4 * inner,), jnp.float32),
        "w_proj": C.dense_init(ks[2], inner, D, dt,
                               scale=1.0 / np.sqrt(2 * cfg.n_layers)),
    }


def slstm_state(cfg: ModelConfig, batch: int) -> Params:
    _, NH, hd = dims(cfg)
    z = lambda: jnp.zeros((batch, NH, hd), jnp.float32)
    return {"c": z(), "n": z(), "h": z(),
            "m": jnp.full((batch, NH, hd), -1e30, jnp.float32)}


def _slstm_step(p: Params, wx_t: Array, state: Params, NH: int, hd: int):
    """wx_t: (B, 4*inner) precomputed W x_t + b. Returns (h_flat, state)."""
    B = wx_t.shape[0]
    rec = jnp.einsum("bhd,hde->bhe", state["h"], p["r"])     # (B,NH,4*hd)
    zall = wx_t.reshape(B, 4, NH, hd).transpose(0, 2, 1, 3).reshape(B, NH, 4 * hd) \
        + rec
    zi, zf, zz, zo = jnp.split(zall, 4, axis=-1)             # (B,NH,hd)
    lf = jax.nn.log_sigmoid(zf)
    li = zi
    m_new = jnp.maximum(lf + state["m"], li)
    fp = jnp.exp(lf + state["m"] - m_new)
    ip = jnp.exp(li - m_new)
    c = fp * state["c"] + ip * jnp.tanh(zz)
    n = fp * state["n"] + ip
    h = jax.nn.sigmoid(zo) * c / jnp.maximum(n, 1e-6)
    return h, {"c": c, "n": n, "h": h, "m": m_new}


def apply_slstm(p: Params, x: Array, cfg: ModelConfig, qcfg: QuantConfig,
                scales: Optional[Params], taps: Optional[Dict],
                n_skip: int = 0, init_state: Optional[Params] = None,
                return_state: bool = False):
    B, S, D = x.shape
    inner, NH, hd = dims(cfg)
    wx = C.qlinear(x, p["w"], None, qcfg, scales, "s_in", taps, n_skip) \
        .astype(jnp.float32) + p["b"]
    state = init_state if init_state is not None else slstm_state(cfg, B)
    state = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (B,) + a.shape).astype(jnp.float32)
        if a.ndim == 2 else a.astype(jnp.float32), state)

    def step(st, wx_t):
        h, st = _slstm_step(p, wx_t, st, NH, hd)
        return st, h

    state, hs = jax.lax.scan(step, state, jnp.transpose(wx, (1, 0, 2)))
    hs = jnp.transpose(hs, (1, 0, 2, 3)).reshape(B, S, inner).astype(x.dtype)
    hs = constrain(hs, "B", None, "M")
    out = C.qlinear(hs, p["w_proj"], None, qcfg, scales, "s_out", taps, n_skip)
    if return_state:
        return out, state
    return out


def decode_slstm(p: Params, x: Array, state: Params, cfg: ModelConfig,
                 qcfg: QuantConfig, scales: Optional[Params],
                 taps: Optional[Dict] = None):
    B = x.shape[0]
    inner, NH, hd = dims(cfg)
    wx = C.qlinear(x, p["w"], None, qcfg, scales, "s_in", taps) \
        .astype(jnp.float32) + p["b"]
    h, state = _slstm_step(p, wx[:, 0], state, NH, hd)
    h = h.reshape(B, 1, inner).astype(x.dtype)
    out = C.qlinear(h, p["w_proj"], None, qcfg, scales, "s_out", taps)
    return out, state


# ---------------------------------------------------------------------------
# Full LM: scan over (mLSTM, sLSTM) pairs
# ---------------------------------------------------------------------------

def n_pairs(cfg: ModelConfig) -> int:
    assert cfg.n_layers % 2 == 0, "xlstm stack expects even layer count"
    return cfg.n_layers // 2


def pair_init(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {"ln_m": C.norm_init(cfg), "mlstm": mlstm_init(k1, cfg),
            "ln_s": C.norm_init(cfg), "slstm": slstm_init(k2, cfg)}


def init_params(cfg: ModelConfig, rng) -> Params:
    k_emb, k_layers = jax.random.split(rng)
    P = n_pairs(cfg)
    layers = jax.vmap(lambda k: pair_init(k, cfg))(jax.random.split(k_layers, P))
    p = C.embed_init(k_emb, cfg)
    p["layers"] = layers
    p["ln_f"] = C.norm_init(cfg)
    return p


def init_cache(cfg: ModelConfig, batch: int, max_seq: int = 0,
               dtype=None) -> Params:
    """State 'cache': stacked over pairs. max_seq unused (O(1) state)."""
    P = n_pairs(cfg)
    m = jax.vmap(lambda _: mlstm_state(cfg, batch))(jnp.arange(P))
    s = jax.vmap(lambda _: slstm_state(cfg, batch))(jnp.arange(P))
    return {"m": m, "s": s}


def cache_roles(cfg: ModelConfig, kv_dtype=None,
                per_slot_scales: bool = False) -> Params:
    """Recurrent-state sharding: batch on B, the head-dim on model.
    kv_dtype is part of the uniform signature (ModelAPI.cache_roles) and
    unused — the recurrent state is never int8."""
    return {"m": {"C": (None, "B", None, None, "M"),
                  "n": (None, "B", None, "M"),
                  "m": (None, "B", None)},
            "s": {"c": (None, "B", None, "M"), "n": (None, "B", None, "M"),
                  "h": (None, "B", None, "M"), "m": (None, "B", None, "M")}}


def cushion_zeros(cfg: ModelConfig, m: int, dtype=None) -> Params:
    """CushionState: trainable initial state (batch-free; broadcast at use).
    `m` (prefix length) has no direct meaning here; state size is fixed.
    Defaults to the model compute dtype (see transformer.cushion_zeros)."""
    dtype = C.dtype_of(cfg) if dtype is None else dtype
    P = n_pairs(cfg)
    inner, NH, hd = dims(cfg)
    return {"state": {
        "m": {"C": jnp.zeros((P, NH, hd, hd), dtype),
              "n": jnp.zeros((P, NH, hd), dtype),
              "m": jnp.full((P, NH), -30.0, dtype)},
        "s": {"c": jnp.zeros((P, NH, hd), dtype),
              "n": jnp.zeros((P, NH, hd), dtype),
              "h": jnp.zeros((P, NH, hd), dtype),
              "m": jnp.full((P, NH, hd), -30.0, dtype)},
    }}


def _bcast_state(st: Params, B: int) -> Params:
    """Broadcast a batch-free cushion state to batch B."""
    def f(a):
        return jnp.broadcast_to(a[:, None], (a.shape[0], B) + a.shape[1:])
    return jax.tree_util.tree_map(f, st)


def forward(params: Params, tokens: Array, cfg: ModelConfig,
            qcfg: QuantConfig, *, scales: Optional[Params] = None,
            cushion: Optional[Params] = None, collect: bool = False,
            n_skip: int = 0, prepend_embeds: Optional[Array] = None,
            remat: bool = True, return_cache: bool = False):
    x = C.embed_tokens(params, tokens, cfg)
    if prepend_embeds is not None:
        x = jnp.concatenate([prepend_embeds.astype(x.dtype), x], axis=1)
    B = x.shape[0]
    P = n_pairs(cfg)
    lscales = C.resolve_scales(scales, SITES, P, qcfg)
    if cushion is not None:
        init_st = _bcast_state(cushion["state"], B)
    else:
        init_st = jax.tree_util.tree_map(
            lambda a: jnp.zeros((0,)), init_cache(cfg, B))  # placeholder
        init_st = None

    def body(h, xs):
        if init_st is None:
            lp, lsc = xs
            st_m = st_s = None
        else:
            lp, lsc, st = xs
            st_m, st_s = st["m"], st["s"]
        taps: Optional[Dict] = {} if collect else None
        if collect:
            taps["block_in"] = Q.site_stats(h, n_skip)
        hn = C.apply_norm(lp["ln_m"], h, cfg)
        o, new_m = apply_mlstm(lp["mlstm"], hn, cfg, qcfg, lsc, taps, n_skip,
                               init_state=st_m, return_state=True)
        h = h + o
        hn = C.apply_norm(lp["ln_s"], h, cfg)
        o, new_s = apply_slstm(lp["slstm"], hn, cfg, qcfg, lsc, taps, n_skip,
                               init_state=st_s, return_state=True)
        h = constrain(h + o, "B")
        return h, ((taps if collect else {}), {"m": new_m, "s": new_s})

    if remat:
        body = jax.checkpoint(body)
    xs = (params["layers"], lscales) if init_st is None \
        else (params["layers"], lscales, init_st)
    x, (layer_taps, states) = jax.lax.scan(body, x, xs)
    x = C.apply_norm(params["ln_f"], x, cfg)
    head_taps: Optional[Dict] = {} if collect else None
    logits = C.lm_head(params, x, cfg, qcfg, scales, head_taps, n_skip)
    taps: Dict = {}
    if collect:
        taps = {"layers": layer_taps, **(head_taps or {}),
                "final_in": Q.site_stats(x, n_skip)}
    if return_cache:
        return logits, taps, states
    return logits, taps


def prefill(params: Params, tokens: Array, cache: Params, cfg: ModelConfig,
            qcfg: QuantConfig, *, scales: Optional[Params] = None,
            cushion: Optional[Params] = None,
            prepend_embeds: Optional[Array] = None, remat: bool = False):
    logits, _, states = forward(params, tokens, cfg, qcfg, scales=scales,
                                cushion=cushion, remat=remat,
                                prepend_embeds=prepend_embeds,
                                return_cache=True)
    S = tokens.shape[1] + (0 if prepend_embeds is None
                           else prepend_embeds.shape[1])
    return logits[:, -1:], states, jnp.asarray(S, jnp.int32)


def decode_step(params: Params, token: Array, pos: Array, cache: Params,
                cfg: ModelConfig, qcfg: QuantConfig, *,
                scales: Optional[Params] = None):
    x = C.embed_tokens(params, token[:, None], cfg)
    P = n_pairs(cfg)
    lscales = C.resolve_scales(scales, SITES, P, qcfg)

    def body(h, xs):
        lp, lsc, st = xs
        hn = C.apply_norm(lp["ln_m"], h, cfg)
        o, new_m = decode_mlstm(lp["mlstm"], hn, st["m"], cfg, qcfg, lsc)
        h = h + o
        hn = C.apply_norm(lp["ln_s"], h, cfg)
        o, new_s = decode_slstm(lp["slstm"], hn, st["s"], cfg, qcfg, lsc)
        h = h + o
        return h, {"m": new_m, "s": new_s}

    x, states = jax.lax.scan(body, x, (params["layers"], lscales, cache))
    x = C.apply_norm(params["ln_f"], x, cfg)
    logits = C.lm_head(params, x, cfg, qcfg, scales, None)
    return logits[:, 0], states


def loss_fn(params: Params, tokens: Array, labels: Array, cfg: ModelConfig,
            qcfg: QuantConfig, *, scales=None, cushion=None,
            collect: bool = False, n_skip: int = 0, remat: bool = True,
            lam: float = 0.0):
    logits, taps = forward(params, tokens, cfg, qcfg, scales=scales,
                           cushion=cushion, collect=collect or lam > 0,
                           n_skip=n_skip, remat=remat)
    if n_skip:
        logits = logits[:, n_skip:]
        labels = labels[:, n_skip:]
    ce = C.cross_entropy(logits, labels)
    loss = ce
    aux = {"ce": ce, "taps": taps}
    if lam > 0 or collect:
        qerr = T.total_qerr(taps)
        aux["qerr"] = qerr
        if lam > 0:
            loss = loss + lam * qerr
    return loss, aux


def placeholder_all_scales(cfg: ModelConfig) -> Params:
    sc = C.placeholder_scales(SITES, n_pairs(cfg))
    sc["head"] = Q.SiteScale(scale=jnp.ones(()), zero=jnp.zeros(()))
    return sc
