"""Mamba selective-SSM block (used standalone and inside the Jamba hybrid).

Training/prefill uses `jax.lax.associative_scan` over the sequence (parallel
prefix-scan of the diagonal linear recurrence — the TPU-native analogue of
the CUDA selective-scan kernel). Decode is a single recurrent step carrying
(conv window, SSM state) — O(1) per token, which is what makes the hybrid
archs runnable at 500k context.

Sites: "mamba_in" (in-projection input), "mamba_out" (out-projection input).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, QuantConfig
from repro.core import quantization as Q
from repro.distributed.sharding import constrain
from repro.models import common as C

Array = jax.Array
Params = Dict[str, Any]

SITES = ("mamba_in", "mamba_out")


def dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    s = cfg.ssm
    inner = s.expand * cfg.d_model
    dt_rank = max(1, int(np.ceil(cfg.d_model / 16)))
    return inner, s.d_state, s.d_conv, dt_rank


def mamba_init(key, cfg: ModelConfig) -> Params:
    inner, d_state, d_conv, dt_rank = dims(cfg)
    D = cfg.d_model
    dt = C.dtype_of(cfg)
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A
    A = jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32)[None, :],
                 (inner, 1))
    p = {
        "w_in": C.dense_init(ks[0], D, 2 * inner, dt),
        "conv_w": (jax.random.normal(ks[1], (d_conv, inner), jnp.float32)
                   / np.sqrt(d_conv)).astype(dt),
        "conv_b": jnp.zeros((inner,), dt),
        "w_x": C.dense_init(ks[2], inner, dt_rank + 2 * d_state, dt),
        "dt_w": C.dense_init(ks[3], dt_rank, inner, dt),
        "dt_b": jnp.log(jnp.exp(
            jnp.exp(jax.random.uniform(ks[4], (inner,), jnp.float32)
                    * (np.log(0.1) - np.log(0.001)) + np.log(0.001))) - 1.0
        ).astype(jnp.float32),
        "A_log": jnp.log(A),
        "Dskip": jnp.ones((inner,), jnp.float32),
        "w_out": C.dense_init(ks[5], inner, D, dt,
                              scale=1.0 / np.sqrt(2 * cfg.n_layers)),
    }
    return p


def _conv_full(x: Array, w: Array, b: Array) -> Array:
    """Causal depthwise conv. x: (B,S,Cin); w: (d_conv, Cin)."""
    d_conv = w.shape[0]
    y = jax.lax.conv_general_dilated(
        x, w[:, None, :],
        window_strides=(1,), padding=[(d_conv - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return y + b


def _ssm_inputs(p: Params, xc: Array, cfg: ModelConfig):
    """xc: (B,S,inner) post-conv. Returns deltaA (B,S,inner,N), deltaBx."""
    inner, d_state, _, dt_rank = dims(cfg)
    proj = xc @ p["w_x"].astype(xc.dtype)
    dt_raw, Bm, Cm = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) @ p["dt_w"].astype(jnp.float32)
                         + p["dt_b"])                        # (B,S,inner)
    A = -jnp.exp(p["A_log"])                                  # (inner,N)
    deltaA = jnp.exp(dt[..., None] * A)                       # (B,S,inner,N)
    deltaBx = (dt * xc.astype(jnp.float32))[..., None] \
        * Bm.astype(jnp.float32)[:, :, None, :]               # (B,S,inner,N)
    return deltaA, deltaBx, Cm.astype(jnp.float32)


def apply_mamba(p: Params, x: Array, cfg: ModelConfig, qcfg: QuantConfig,
                scales: Optional[Params], taps: Optional[Dict],
                n_skip: int = 0,
                init_state: Optional[Params] = None,
                return_state: bool = False):
    """Full-sequence Mamba mixer. init_state: {"h": (B,inner,N) or (inner,N),
    "conv": (B,d_conv-1,inner)} — the CushionState analogue of prefix KV."""
    B, S, D = x.shape
    inner, d_state, d_conv, _ = dims(cfg)
    xz = C.qlinear(x, p["w_in"], None, qcfg, scales, "mamba_in", taps, n_skip)
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = constrain(xin, "B", None, "M")

    if init_state is not None and "conv" in init_state:
        cv = init_state["conv"]
        if cv.ndim == 2:
            cv = jnp.broadcast_to(cv[None], (B,) + cv.shape)
        xpad = jnp.concatenate([cv.astype(xin.dtype), xin], axis=1)
        xc = _conv_full(xpad, p["conv_w"], p["conv_b"])[:, d_conv - 1:]
    else:
        xc = _conv_full(xin, p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc)

    deltaA, deltaBx, Cm = _ssm_inputs(p, xc, cfg)
    if init_state is not None and "h" in init_state:
        h0 = init_state["h"].astype(jnp.float32)
        if h0.ndim == 2:
            h0 = jnp.broadcast_to(h0[None], (B,) + h0.shape)
        # fold h0 into the first step: h_1 = A_1 h_0 + Bx_1
        deltaBx = deltaBx.at[:, 0].add(deltaA[:, 0] * h0)

    def combine(a, b):
        (a1, b1), (a2, b2) = a, b
        return a2 * a1, a2 * b1 + b2

    deltaA = constrain(deltaA, "B", None, "M", None)
    deltaBx = constrain(deltaBx, "B", None, "M", None)
    _, hs = jax.lax.associative_scan(combine, (deltaA, deltaBx), axis=1)
    y = jnp.einsum("bsin,bsn->bsi", hs, Cm) \
        + p["Dskip"] * xc.astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    y = constrain(y, "B", None, "M")
    out = C.qlinear(y, p["w_out"], None, qcfg, scales, "mamba_out", taps,
                    n_skip)
    if return_state:
        state = {"h": hs[:, -1],
                 "conv": jnp.concatenate(
                     [jnp.zeros((B, d_conv - 1, inner), xin.dtype), xin],
                     axis=1)[:, -(d_conv - 1):]}
        return out, state
    return out


def init_state(cfg: ModelConfig, batch: int) -> Params:
    inner, d_state, d_conv, _ = dims(cfg)
    return {"h": jnp.zeros((batch, inner, d_state), jnp.float32),
            "conv": jnp.zeros((batch, d_conv - 1, inner), C.dtype_of(cfg))}


def decode_mamba(p: Params, x: Array, state: Params, cfg: ModelConfig,
                 qcfg: QuantConfig, scales: Optional[Params],
                 taps: Optional[Dict] = None):
    """Single-token step. x: (B,1,D); state: {"h": (B,inner,N),
    "conv": (B,d_conv-1,inner)}."""
    B = x.shape[0]
    inner, d_state, d_conv, _ = dims(cfg)
    xz = C.qlinear(x, p["w_in"], None, qcfg, scales, "mamba_in", taps)
    xin, z = jnp.split(xz, 2, axis=-1)           # (B,1,inner)
    win = jnp.concatenate([state["conv"].astype(xin.dtype), xin], axis=1)
    xc = jnp.einsum("bci,ci->bi", win, p["conv_w"].astype(xin.dtype)) \
        + p["conv_b"]
    xc = jax.nn.silu(xc)[:, None]                # (B,1,inner)
    deltaA, deltaBx, Cm = _ssm_inputs(p, xc, cfg)
    h = deltaA[:, 0] * state["h"] + deltaBx[:, 0]
    y = jnp.einsum("bin,bn->bi", h, Cm[:, 0]) \
        + p["Dskip"] * xc[:, 0].astype(jnp.float32)
    y = (y[:, None].astype(x.dtype)) * jax.nn.silu(z)
    out = C.qlinear(y, p["w_out"], None, qcfg, scales, "mamba_out", taps)
    new_state = {"h": h, "conv": win[:, 1:]}
    return out, new_state
