"""Whisper-style encoder-decoder backbone. The audio conv frontend is a STUB
per the assignment: inputs are precomputed frame embeddings (B, T_enc, D).

Decoder: causal self-attention (with optional CushionCache prefix KV — the
paper's technique applied to the decoder; see DESIGN.md §5) + cross-attention
over encoder states + MLP.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, QuantConfig
from repro.core import quantization as Q
from repro.distributed.sharding import constrain
from repro.models import common as C
from repro.models import transformer as T

Array = jax.Array
Params = Dict[str, Any]

ENC_SITES = ("qkv", "o", "mlp_in", "down")
DEC_SITES = ("qkv", "o", "xq", "xo", "mlp_in", "down")

# Greedy-search scoring fallback: decoder L_q depends on cross-attention
# over the per-sample encoder states, which the shared-prefix KV cache
# cannot capture; the search falls back to
# `cushioncache.greedy_search_ref` (full forward per candidate).
SUPPORTS_PREFIX_KV_SCORING = False

# Continuous-batching slot layout: decoder self-attention KV plus the
# precomputed cross-attention KV all live at (L, B, S/T_enc, K, hd) —
# batch axis 1 everywhere. Scattering xk/xv with the row carries each
# request's *own* encoder states into its slot, so slots transcribing
# different audio decode together in one lock-step batch.
CACHE_BATCH_AXES = {"k": 1, "v": 1, "xk": 1, "xv": 1}


def xattn_init(key, cfg: ModelConfig) -> Params:
    hd, H, K = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 3)
    dt = C.dtype_of(cfg)
    return {"wq": C.dense_init(ks[0], cfg.d_model, H * hd, dt),
            "wkv": C.dense_init(ks[1], cfg.d_model, 2 * K * hd, dt),
            "wo": C.dense_init(ks[2], H * hd, cfg.d_model, dt,
                               scale=1.0 / np.sqrt(2 * cfg.n_layers))}


def cross_attention(p: Params, x: Array, enc_kv: Tuple[Array, Array],
                    cfg: ModelConfig, qcfg: QuantConfig,
                    scales: Optional[Params], taps: Optional[Dict],
                    n_skip: int = 0) -> Array:
    """x: (B,S,D); enc_kv: (k, v) each (B,T,K,hd) precomputed from encoder."""
    B, S, _ = x.shape
    hd, H = cfg.head_dim, cfg.n_heads
    q = C.qlinear(x, p["wq"], None, qcfg, scales, "xq", taps, n_skip)
    q = q.reshape(B, S, H, hd)
    k, v = enc_kv
    out = C._sdpa(q, k, v, None, cfg)
    out = out.reshape(B, S, H * hd)
    return C.qlinear(out, p["wo"], None, qcfg, scales, "xo", taps, n_skip)


def enc_kv(p: Params, enc_out: Array, cfg: ModelConfig) -> Tuple[Array, Array]:
    B, Te, _ = enc_out.shape
    K, hd = cfg.n_kv_heads, cfg.head_dim
    kv = enc_out @ p["wkv"]
    k, v = jnp.split(kv, 2, axis=-1)
    return k.reshape(B, Te, K, hd), v.reshape(B, Te, K, hd)


def enc_layer_init(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {"ln1": C.norm_init(cfg), "attn": C.attn_init(k1, cfg),
            "ln2": C.norm_init(cfg), "mlp": C.mlp_init(k2, cfg)}


def dec_layer_init(key, cfg: ModelConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": C.norm_init(cfg), "attn": C.attn_init(k1, cfg),
            "lnx": C.norm_init(cfg), "xattn": xattn_init(k2, cfg),
            "ln2": C.norm_init(cfg), "mlp": C.mlp_init(k3, cfg)}


def init_params(cfg: ModelConfig, rng) -> Params:
    ke, kd, kemb = jax.random.split(rng, 3)
    ne = cfg.encdec.encoder_layers
    p = C.embed_init(kemb, cfg)
    p["encoder"] = jax.vmap(lambda k: enc_layer_init(k, cfg))(
        jax.random.split(ke, ne))
    p["decoder"] = jax.vmap(lambda k: dec_layer_init(k, cfg))(
        jax.random.split(kd, cfg.n_layers))
    p["ln_enc"] = C.norm_init(cfg)
    p["ln_f"] = C.norm_init(cfg)
    return p


def encode(params: Params, frames: Array, cfg: ModelConfig,
           qcfg: QuantConfig, scales: Optional[Params] = None,
           collect: bool = False, remat: bool = True):
    """frames: (B, T_enc, D) precomputed frame embeddings (stub frontend)."""
    x = frames.astype(C.dtype_of(cfg))
    x = constrain(x, "B")
    Te = x.shape[1]
    positions = jnp.arange(Te)
    ne = cfg.encdec.encoder_layers
    lscales = C.resolve_scales(scales["enc"] if scales is not None
                               else None, ENC_SITES, ne, qcfg)

    def body(h, xs):
        lp, lsc = xs
        taps: Optional[Dict] = {} if collect else None
        hn = C.apply_norm(lp["ln1"], h, cfg)
        a = C.attention_full(lp["attn"], hn, cfg, qcfg, lsc, taps, positions,
                             causal=False)
        h = h + a
        hn = C.apply_norm(lp["ln2"], h, cfg)
        h = h + C.apply_mlp(lp["mlp"], hn, cfg, qcfg, lsc, taps)
        h = constrain(h, "B")
        return h, (taps if collect else {})

    if remat:
        body = jax.checkpoint(body)
    x, enc_taps = jax.lax.scan(body, x, (params["encoder"], lscales))
    return C.apply_norm(params["ln_enc"], x, cfg), enc_taps


def forward(params: Params, tokens: Array, cfg: ModelConfig,
            qcfg: QuantConfig, *, frames: Array,
            scales: Optional[Params] = None,
            cushion: Optional[Params] = None, collect: bool = False,
            n_skip: int = 0, remat: bool = True):
    """Teacher-forced decoder pass. frames: (B,T_enc,D)."""
    enc_out, enc_taps = encode(params, frames, cfg, qcfg, scales, collect,
                               remat)
    x = C.embed_tokens(params, tokens, cfg)
    S = x.shape[1]
    m = 0 if cushion is None else cushion["kv"]["k"].shape[1]
    positions = m + jnp.arange(S)
    L = cfg.n_layers
    lscales = C.resolve_scales(scales["dec"] if scales is not None
                               else None, DEC_SITES, L, qcfg)
    pre = cushion["kv"] if cushion is not None else {
        "k": jnp.zeros((L, 0, cfg.n_kv_heads, cfg.head_dim), x.dtype),
        "v": jnp.zeros((L, 0, cfg.n_kv_heads, cfg.head_dim), x.dtype)}

    def body(h, xs):
        lp, lsc, lpre = xs
        taps: Optional[Dict] = {} if collect else None
        if collect:
            taps["block_in"] = Q.site_stats(h, n_skip)
        hn = C.apply_norm(lp["ln1"], h, cfg)
        a = C.attention_full(lp["attn"], hn, cfg, qcfg, lsc, taps, positions,
                             prefix_kv=lpre, causal=True, n_skip=n_skip)
        h = h + a
        hn = C.apply_norm(lp["lnx"], h, cfg)
        kv = enc_kv(lp["xattn"], enc_out, cfg)
        h = h + cross_attention(lp["xattn"], hn, kv, cfg, qcfg, lsc, taps,
                                n_skip)
        hn = C.apply_norm(lp["ln2"], h, cfg)
        h = h + C.apply_mlp(lp["mlp"], hn, cfg, qcfg, lsc, taps, n_skip)
        h = constrain(h, "B")
        return h, (taps if collect else {})

    if remat:
        body = jax.checkpoint(body)
    x, dec_taps = jax.lax.scan(body, x, (params["decoder"], lscales, pre))
    x = C.apply_norm(params["ln_f"], x, cfg)
    head_taps: Optional[Dict] = {} if collect else None
    logits = C.lm_head(params, x, cfg, qcfg, scales, head_taps, n_skip)
    taps: Dict = {}
    if collect:
        taps = {"enc_layers": enc_taps, "layers": dec_taps,
                **(head_taps or {}), "final_in": Q.site_stats(x, n_skip)}
    return logits, taps


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None) -> Params:
    dt = dtype or C.dtype_of(cfg)
    K, hd, L = cfg.n_kv_heads, cfg.head_dim, cfg.n_layers
    Te = cfg.encdec.encoder_seq
    return {"k": jnp.zeros((L, batch, max_seq, K, hd), dt),
            "v": jnp.zeros((L, batch, max_seq, K, hd), dt),
            "xk": jnp.zeros((L, batch, Te, K, hd), dt),
            "xv": jnp.zeros((L, batch, Te, K, hd), dt)}


cushion_zeros = T.cushion_zeros


def cache_roles(cfg: ModelConfig, kv_dtype=None,
                per_slot_scales: bool = False) -> Params:
    """Self- and cross-attention KV (L, B, S, K, hd): heads axis on "M",
    matching the serve-pool layout (see transformer.cache_roles). kv_dtype
    is part of the uniform signature and unused (encdec KV stays fp)."""
    kv = (None, "B", None, "M", None)
    return {"k": kv, "v": kv, "xk": kv, "xv": kv}


def prefill(params: Params, tokens: Array, cache: Params, cfg: ModelConfig,
            qcfg: QuantConfig, *, frames: Array,
            scales: Optional[Params] = None,
            cushion: Optional[Params] = None, remat: bool = False):
    enc_out, _ = encode(params, frames, cfg, qcfg, scales, False, remat)
    x = C.embed_tokens(params, tokens, cfg)
    B, S, _ = x.shape
    m = 0 if cushion is None else cushion["kv"]["k"].shape[1]
    positions = m + jnp.arange(S)
    L = cfg.n_layers
    lscales = C.resolve_scales(scales["dec"] if scales is not None
                               else None, DEC_SITES, L, qcfg)
    pre = cushion["kv"] if cushion is not None else {
        "k": jnp.zeros((L, 0, cfg.n_kv_heads, cfg.head_dim), x.dtype),
        "v": jnp.zeros((L, 0, cfg.n_kv_heads, cfg.head_dim), x.dtype)}

    def body(h, xs):
        lp, lsc, lpre = xs
        hn = C.apply_norm(lp["ln1"], h, cfg)
        a, kv = C.attention_full(lp["attn"], hn, cfg, qcfg, lsc, None,
                                 positions, prefix_kv=lpre, causal=True,
                                 return_kv=True)
        h = h + a
        hn = C.apply_norm(lp["lnx"], h, cfg)
        xkv = enc_kv(lp["xattn"], enc_out, cfg)
        h = h + cross_attention(lp["xattn"], hn, xkv, cfg, qcfg, lsc, None)
        hn = C.apply_norm(lp["ln2"], h, cfg)
        h = h + C.apply_mlp(lp["mlp"], hn, cfg, qcfg, lsc, None)
        h = constrain(h, "B")
        return h, (kv, xkv)

    x, ((ks, vs), (xks, xvs)) = jax.lax.scan(
        body, x, (params["decoder"], lscales, pre))
    cache, m2 = T.write_cushion_to_cache(
        {"k": cache["k"], "v": cache["v"]}, cushion)
    cache = {"k": jax.lax.dynamic_update_slice(
                 cache["k"], ks.astype(cache["k"].dtype), (0, 0, m, 0, 0)),
             "v": jax.lax.dynamic_update_slice(
                 cache["v"], vs.astype(cache["v"].dtype), (0, 0, m, 0, 0)),
             "xk": xks.astype(C.dtype_of(cfg)),
             "xv": xvs.astype(C.dtype_of(cfg))}
    x = C.apply_norm(params["ln_f"], x, cfg)
    logits = C.lm_head(params, x[:, -1:], cfg, qcfg, None, None)
    return logits, cache, jnp.asarray(m + S, jnp.int32)


def decode_step(params: Params, token: Array, pos: Array, cache: Params,
                cfg: ModelConfig, qcfg: QuantConfig, *,
                scales: Optional[Params] = None):
    x = C.embed_tokens(params, token[:, None], cfg)
    L = cfg.n_layers
    lscales = C.resolve_scales(scales["dec"] if scales is not None
                               else None, DEC_SITES, L, qcfg)

    def body(h, xs):
        lp, lsc, ck, cv, xk, xv = xs
        hn = C.apply_norm(lp["ln1"], h, cfg)
        a, ck, cv = C.attention_decode(lp["attn"], hn, ck, cv, pos, cfg,
                                       qcfg, lsc, None)
        h = h + a
        hn = C.apply_norm(lp["lnx"], h, cfg)
        h = h + cross_attention(lp["xattn"], hn, (xk, xv), cfg, qcfg, lsc,
                                None)
        hn = C.apply_norm(lp["ln2"], h, cfg)
        h = h + C.apply_mlp(lp["mlp"], hn, cfg, qcfg, lsc, None)
        return h, (ck, cv)

    x, (ks, vs) = jax.lax.scan(body, x, (params["decoder"], lscales,
                                         cache["k"], cache["v"],
                                         cache["xk"], cache["xv"]))
    cache = {"k": ks, "v": vs, "xk": cache["xk"], "xv": cache["xv"]}
    x = C.apply_norm(params["ln_f"], x, cfg)
    logits = C.lm_head(params, x, cfg, qcfg, None, None)
    return logits[:, 0], cache


def loss_fn(params: Params, tokens: Array, labels: Array, cfg: ModelConfig,
            qcfg: QuantConfig, *, frames: Array, scales=None, cushion=None,
            collect: bool = False, n_skip: int = 0, remat: bool = True,
            lam: float = 0.0):
    logits, taps = forward(params, tokens, cfg, qcfg, frames=frames,
                           scales=scales, cushion=cushion,
                           collect=collect or lam > 0, n_skip=n_skip,
                           remat=remat)
    if n_skip:
        logits = logits[:, n_skip:]
        labels = labels[:, n_skip:]
    ce = C.cross_entropy(logits, labels)
    loss = ce
    aux = {"ce": ce, "taps": taps}
    if lam > 0 or collect:
        qerr = T.total_qerr(taps)
        aux["qerr"] = qerr
        if lam > 0:
            loss = loss + lam * qerr
    return loss, aux


def placeholder_all_scales(cfg: ModelConfig) -> Params:
    return {"enc": C.placeholder_scales(ENC_SITES, cfg.encdec.encoder_layers),
            "dec": C.placeholder_scales(DEC_SITES, cfg.n_layers),
            "head": Q.SiteScale(scale=jnp.ones(()), zero=jnp.zeros(()))}
