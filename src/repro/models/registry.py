"""Uniform model API over all families: build(cfg) -> ModelAPI.

Batch dicts:
  dense/moe         {"tokens", "labels"}
  ssm/hybrid        {"tokens", "labels"}
  encdec            + {"frames":  (B, T_enc, D)}   (stub audio frontend)
  vlm               + {"patches": (B, P, D)}       (stub vision frontend)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import Family, ModelConfig, QuantConfig
from repro.models import common as C
from repro.models import encdec as ED
from repro.models import hybrid as HY
from repro.models import moe as MO
from repro.models import ssm  # noqa: F401  (used inside hybrid)
from repro.models import transformer as TR
from repro.models import vlm as VL
from repro.models import xlstm as XL

Params = Dict[str, Any]


def family_module(cfg: ModelConfig):
    return {
        Family.DENSE: TR, Family.MOE: MO, Family.SSM: XL,
        Family.HYBRID: HY, Family.ENCDEC: ED, Family.VLM: VL,
    }[cfg.family]


def _extra_kwargs(cfg: ModelConfig, batch: Dict[str, Any]) -> Dict[str, Any]:
    if cfg.family == Family.ENCDEC:
        return {"frames": batch["frames"]}
    if cfg.family == Family.VLM:
        return {"patches": batch["patches"]}
    return {}


@dataclasses.dataclass
class ModelAPI:
    cfg: ModelConfig
    mod: Any

    @property
    def sites(self) -> Tuple[str, ...]:
        if self.cfg.family == Family.ENCDEC:
            return ED.DEC_SITES
        return self.mod.SITES

    def init_params(self, rng) -> Params:
        return self.mod.init_params(self.cfg, rng)

    def loss_fn(self, params, batch, qcfg: QuantConfig, **kw):
        return self.mod.loss_fn(params, batch["tokens"], batch["labels"],
                                self.cfg, qcfg,
                                **_extra_kwargs(self.cfg, batch), **kw)

    def forward(self, params, batch, qcfg: QuantConfig, **kw):
        return self.mod.forward(params, batch["tokens"], self.cfg, qcfg,
                                **_extra_kwargs(self.cfg, batch), **kw)

    def init_cache(self, batch: int, max_seq: int, dtype=None,
                   kv_dtype=None, prefix_len: int = 0):
        """kv_dtype "int8" requests quantized KV storage (attention-cache
        families only); prefix_len sizes the protected fp cushion block."""
        if kv_dtype is None:
            return self.mod.init_cache(self.cfg, batch, max_seq, dtype=dtype)
        if self.cfg.family not in (Family.DENSE, Family.MOE, Family.VLM,
                                   Family.HYBRID):
            raise ValueError(
                f"kv_dtype={kv_dtype!r} unsupported for {self.cfg.family}")
        return self.mod.init_cache(self.cfg, batch, max_seq, dtype=dtype,
                                   kv_dtype=kv_dtype, prefix_len=prefix_len)

    def prefill(self, params, batch, cache, qcfg: QuantConfig, **kw):
        return self.mod.prefill(params, batch["tokens"], cache, self.cfg,
                                qcfg, **_extra_kwargs(self.cfg, batch), **kw)

    def decode_step(self, params, token, pos, cache, qcfg: QuantConfig, **kw):
        return self.mod.decode_step(params, token, pos, cache, self.cfg,
                                    qcfg, **kw)

    def cushion_zeros(self, m: int, dtype=jnp.float32):
        return self.mod.cushion_zeros(self.cfg, m, dtype=dtype)

    def forward_with_token_prefix(self, params, prefix_ids, batch,
                                  qcfg: QuantConfig, **kw):
        """Forward with a prefix of *real tokens* placed where the cushion
        will sit at deployment (greedy search, paper §4.1). prefix_ids: (m,)
        int32. Returns (logits, taps); callers pass collect/n_skip via kw."""
        cfg = self.cfg
        m = prefix_ids.shape[0]
        if cfg.family == Family.VLM:
            # cushion sits before the patches: prepend embed(prefix)+patches
            pre = jnp.take(params["embed"]["w"], prefix_ids, axis=0)[None]
            pre = jnp.broadcast_to(
                pre, (batch["patches"].shape[0],) + pre.shape[1:])
            pre = jnp.concatenate(
                [pre.astype(batch["patches"].dtype), batch["patches"]], axis=1)
            return TR.forward(params, batch["tokens"], cfg, qcfg,
                              prepend_embeds=pre, **kw)
        toks = jnp.concatenate(
            [jnp.broadcast_to(prefix_ids[None],
                              (batch["tokens"].shape[0], m)),
             batch["tokens"]], axis=1)
        nb = dict(batch)
        nb["tokens"] = toks
        return self.forward(params, nb, qcfg, **kw)

    def extract_cushion(self, params, prefix_ids, batch,
                        qcfg: QuantConfig) -> Params:
        """Turn a searched token prefix into the deployment Cushion artifact
        (per-layer KV for attention archs; recurrent states for SSM/hybrid)
        by running the prefix through the model once (paper: 'their keys and
        values are cached and reused at inference', eq. 8)."""
        cfg = self.cfg
        m = int(prefix_ids.shape[0])
        toks = prefix_ids[None]
        if cfg.family == Family.SSM:
            _, _, states = XL.forward(params, toks, cfg, qcfg,
                                      return_cache=True, remat=False)
            return {"state": jax.tree_util.tree_map(lambda a: a[:, 0], states)}
        if cfg.family == Family.HYBRID:
            cache = HY.init_cache(cfg, 1, m)
            _, cache, _ = HY.prefill(params, toks, cache, cfg, qcfg)
            return {"kv": {"k": cache["k"][:, 0, :m], "v": cache["v"][:, 0, :m]},
                    "state": {"h": cache["h"][:, :, 0],
                              "conv": cache["conv"][:, :, 0]}}
        if cfg.family == Family.ENCDEC:
            # null acoustic context for the prefix pass (DESIGN.md §5)
            frames = jnp.zeros((1, cfg.encdec.encoder_seq, cfg.d_model),
                               C.dtype_of(cfg))
            cache = ED.init_cache(cfg, 1, m)
            _, cache, _ = ED.prefill(params, toks, cache, cfg, qcfg,
                                     frames=frames)
            return {"kv": {"k": cache["k"][:, 0, :m],
                           "v": cache["v"][:, 0, :m]}}
        mod = MO if cfg.family == Family.MOE else TR
        cache = mod.init_cache(cfg, 1, m)
        _, cache, _ = mod.prefill(params, toks, cache, cfg, qcfg)
        return {"kv": {"k": cache["k"][:, 0, :m], "v": cache["v"][:, 0, :m]}}

    # ------------------------------------------------------------------
    # Input construction
    # ------------------------------------------------------------------

    def make_batch(self, rng, batch: int, seq_len: int) -> Dict[str, Any]:
        """Concrete random batch (smoke tests / CPU experiments)."""
        cfg = self.cfg
        text_len = self.text_len(seq_len)
        toks = jax.random.randint(rng, (batch, text_len + 1), 0,
                                  cfg.vocab_size, dtype=jnp.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.family == Family.ENCDEC:
            out["frames"] = jax.random.normal(
                rng, (batch, cfg.encdec.encoder_seq, cfg.d_model),
                C.dtype_of(cfg)) * 0.02
        if cfg.family == Family.VLM:
            out["patches"] = jax.random.normal(
                rng, (batch, cfg.vlm.num_patches, cfg.d_model),
                C.dtype_of(cfg)) * 0.02
        return out

    def text_len(self, seq_len: int) -> int:
        """Token count such that total positions == seq_len."""
        if self.cfg.family == Family.VLM:
            return max(1, seq_len - self.cfg.vlm.num_patches)
        return seq_len

    def input_specs(self, batch: int, seq_len: int) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input (dry-run)."""
        cfg = self.cfg
        text_len = self.text_len(seq_len)
        sds = jax.ShapeDtypeStruct
        out = {"tokens": sds((batch, text_len), jnp.int32),
               "labels": sds((batch, text_len), jnp.int32)}
        if cfg.family == Family.ENCDEC:
            out["frames"] = sds((batch, cfg.encdec.encoder_seq, cfg.d_model),
                                C.dtype_of(cfg))
        if cfg.family == Family.VLM:
            out["patches"] = sds((batch, cfg.vlm.num_patches, cfg.d_model),
                                 C.dtype_of(cfg))
        return out


def build(cfg: ModelConfig) -> ModelAPI:
    return ModelAPI(cfg=cfg, mod=family_module(cfg))
