"""Uniform model API over all families: build(cfg) -> ModelAPI.

Batch dicts:
  dense/moe         {"tokens", "labels"}
  ssm/hybrid        {"tokens", "labels"}
  encdec            + {"frames":  (B, T_enc, D)}   (stub audio frontend)
  vlm               + {"patches": (B, P, D)}       (stub vision frontend)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import Family, ModelConfig, QuantConfig
from repro.models import common as C
from repro.models import encdec as ED
from repro.models import hybrid as HY
from repro.models import moe as MO
from repro.models import ssm  # noqa: F401  (used inside hybrid)
from repro.models import transformer as TR
from repro.models import vlm as VL
from repro.models import xlstm as XL

Params = Dict[str, Any]


def family_module(cfg: ModelConfig):
    return {
        Family.DENSE: TR, Family.MOE: MO, Family.SSM: XL,
        Family.HYBRID: HY, Family.ENCDEC: ED, Family.VLM: VL,
    }[cfg.family]


def _extra_kwargs(cfg: ModelConfig, batch: Dict[str, Any]) -> Dict[str, Any]:
    if cfg.family == Family.ENCDEC:
        return {"frames": batch["frames"]}
    if cfg.family == Family.VLM:
        return {"patches": batch["patches"]}
    return {}


@dataclasses.dataclass
class ModelAPI:
    cfg: ModelConfig
    mod: Any

    @property
    def sites(self) -> Tuple[str, ...]:
        if self.cfg.family == Family.ENCDEC:
            return ED.DEC_SITES
        return self.mod.SITES

    def init_params(self, rng) -> Params:
        return self.mod.init_params(self.cfg, rng)

    def loss_fn(self, params, batch, qcfg: QuantConfig, **kw):
        return self.mod.loss_fn(params, batch["tokens"], batch["labels"],
                                self.cfg, qcfg,
                                **_extra_kwargs(self.cfg, batch), **kw)

    def forward(self, params, batch, qcfg: QuantConfig, **kw):
        return self.mod.forward(params, batch["tokens"], self.cfg, qcfg,
                                **_extra_kwargs(self.cfg, batch), **kw)

    def init_cache(self, batch: int, max_seq: int, dtype=None,
                   kv_dtype=None, prefix_len: int = 0,
                   per_slot_scales: bool = False):
        """kv_dtype "int8" requests quantized KV storage (attention-cache
        families only); prefix_len sizes the protected fp cushion block;
        per_slot_scales gives every batch row its own dequant scales
        (continuous-batching pools, calibrated per admission prefill)."""
        if kv_dtype is None:
            return self.mod.init_cache(self.cfg, batch, max_seq, dtype=dtype)
        if self.cfg.family not in (Family.DENSE, Family.MOE, Family.VLM,
                                   Family.HYBRID):
            raise ValueError(
                f"kv_dtype={kv_dtype!r} unsupported for {self.cfg.family}")
        return self.mod.init_cache(self.cfg, batch, max_seq, dtype=dtype,
                                   kv_dtype=kv_dtype, prefix_len=prefix_len,
                                   per_slot_scales=per_slot_scales)

    def prefill(self, params, batch, cache, qcfg: QuantConfig, **kw):
        return self.mod.prefill(params, batch["tokens"], cache, self.cfg,
                                qcfg, **_extra_kwargs(self.cfg, batch), **kw)

    def decode_step(self, params, token, pos, cache, qcfg: QuantConfig, **kw):
        """pos: () shared absolute position, or (B,) per-row positions for
        continuous batching (each cache slot decodes at its own offset)."""
        return self.mod.decode_step(params, token, pos, cache, self.cfg,
                                    qcfg, **kw)

    def cache_roles(self, kv_dtype=None,
                    per_slot_scales: bool = False) -> Dict[str, Tuple]:
        """Sharding-role template of every cache leaf (leaf name -> axis
        roles), consumed by ``distributed.sharding.cache_shardings`` to lay
        a serving pool out over a tp mesh. Families without a template
        (ssm's shape-polymorphic state, encdec) serve replicated."""
        fn = getattr(self.mod, "cache_roles", None)
        if fn is None:
            return {}
        return fn(self.cfg, kv_dtype=kv_dtype,
                  per_slot_scales=per_slot_scales)

    @property
    def cache_batch_axes(self) -> Dict[str, int]:
        """Batch axis of every per-request cache leaf — the continuous-
        batching scheduler's slot-scatter map. Entries may be nested dicts
        (per-leaf axes for state trees — ssm's stacked mLSTM/sLSTM states).
        Every registry family defines one: dense/moe/vlm (flat KV), hybrid
        (KV + Mamba state), ssm (recurrent state tree), encdec (self- +
        cross-attention KV)."""
        axes = getattr(self.mod, "CACHE_BATCH_AXES", None)
        if axes is None:
            raise NotImplementedError(
                f"{self.cfg.family}: no continuous-batching slot layout; "
                "use serving.engine.Engine (static batch)")
        return axes

    @property
    def paged_kv_leaves(self) -> Tuple[str, ...]:
        """Cache leaves the paged continuous pool re-lays into a flat page
        store + per-slot page table (``ContinuousEngine(paged=True)``).
        Empty for families without a pageable sequence cache (ssm's
        recurrent state, encdec's per-request cross-KV)."""
        return tuple(getattr(self.mod, "PAGED_KV_LEAVES", ()))

    @property
    def supports_chunked_prefill(self) -> bool:
        """True when prefill() accepts pos_offset to resume a partially
        staged B=1 fp row (chunked admission). Families whose prompt pass
        is not a pure causal attention-KV scan (ssm, encdec, hybrid's Mamba
        leaves, vlm's patch prepend) admit blocking instead."""
        return bool(getattr(self.mod, "SUPPORTS_CHUNKED_PREFILL", False))

    def finalize_staged_kv(self, row, cache, cushion, S: int):
        """Rebuild the admission row a blocking prefill would have produced
        from a finished chunk-staged fp row (int8 pools recalibrate their
        per-slot scales over the whole prompt here)."""
        return self.mod.finalize_staged_kv(row, cache, cushion, S)

    def cushion_zeros(self, m: int, dtype=None):
        """Zero cushion artifact; dtype=None follows the model compute
        dtype — the same dtype `extract_cushion` emits, so a zeros
        template and a real artifact are always interchangeable."""
        return self.mod.cushion_zeros(self.cfg, m, dtype=dtype)

    def forward_with_token_prefix(self, params, prefix_ids, batch,
                                  qcfg: QuantConfig, **kw):
        """Forward with a prefix of *real tokens* placed where the cushion
        will sit at deployment (greedy search, paper §4.1). prefix_ids: (m,)
        int32. Returns (logits, taps); callers pass collect/n_skip via kw."""
        cfg = self.cfg
        m = prefix_ids.shape[0]
        if cfg.family == Family.VLM:
            # cushion sits before the patches: prepend embed(prefix)+patches
            pre = jnp.take(params["embed"]["w"], prefix_ids, axis=0)[None]
            pre = jnp.broadcast_to(
                pre, (batch["patches"].shape[0],) + pre.shape[1:])
            pre = jnp.concatenate(
                [pre.astype(batch["patches"].dtype), batch["patches"]], axis=1)
            return TR.forward(params, batch["tokens"], cfg, qcfg,
                              prepend_embeds=pre, **kw)
        toks = jnp.concatenate(
            [jnp.broadcast_to(prefix_ids[None],
                              (batch["tokens"].shape[0], m)),
             batch["tokens"]], axis=1)
        nb = dict(batch)
        nb["tokens"] = toks
        return self.forward(params, nb, qcfg, **kw)

    # ------------------------------------------------------------------
    # Greedy-search scoring fast path (KV reuse; paper §4.1)
    # ------------------------------------------------------------------
    #
    # Scoring contract: for families whose prefix artifact is pure attention
    # KV (dense / moe / vlm), the shared prefix is prefilled into a KV cache
    # ONCE per search iteration (`prefix_kv`); every candidate is then scored
    # by forwarding [candidate; sample] against that cached block
    # (`score_candidates`), and the no-candidate baseline by forwarding the
    # sample alone (`prefix_qerr`). All three take a prefix padded to a fixed
    # length plus a live-length scalar, so one compiled executable serves the
    # whole search. Recurrent/cross-attention families (ssm / hybrid /
    # encdec) cannot mask a padded prefix out of their state and fall back to
    # `cushioncache.greedy_search_ref` (full forward per candidate).

    @property
    def supports_kv_scoring(self) -> bool:
        return bool(getattr(self.mod, "SUPPORTS_PREFIX_KV_SCORING", False))

    def prefix_kv(self, params, prefix_ids, qcfg: QuantConfig,
                  scales: Optional[Params] = None) -> Params:
        """Stacked per-layer KV {"k","v": (L, m, K, hd)} of a token prefix —
        the shared artifact the scoring fast path prefills once per search
        iteration. With a padded prefix, rows past the live length hold
        garbage by construction; downstream consumers mask them via
        `prefix_valid`."""
        if not self.supports_kv_scoring:
            raise NotImplementedError(
                f"{self.cfg.family}: prefix artifact is not pure attention "
                "KV; use cushioncache.greedy_search_ref")
        cfg = self.cfg
        mod = MO if cfg.family == Family.MOE else TR
        m = prefix_ids.shape[0]
        cache = mod.init_cache(cfg, 1, m)
        _, cache, _ = mod.prefill(params, prefix_ids[None], cache, cfg, qcfg,
                                  scales=scales)
        return {"k": cache["k"][:, 0], "v": cache["v"][:, 0]}

    def prefix_qerr(self, params, prefix_kv, live_len, batch,
                    qcfg: QuantConfig, scales: Optional[Params] = None):
        """L_q of the calibration sample given the cached prefix (the
        search's base error). live_len: dynamic scalar — number of live rows
        in the padded prefix_kv."""
        valid = jnp.arange(prefix_kv["k"].shape[1]) < live_len
        _, taps = self.forward(params, batch, qcfg, scales=scales,
                               cushion={"kv": prefix_kv}, collect=True,
                               n_skip=0, prefix_valid=valid,
                               pos_offset=live_len, remat=False)
        return TR.total_qerr(taps)

    def score_candidates(self, params, prefix_kv, live_len, cand_ids, batch,
                         qcfg: QuantConfig, scales: Optional[Params] = None):
        """(N,) L_q of each candidate-extended prefix, reusing the shared
        prefix KV: per candidate, one forward of [candidate; sample] with
        the cached prefix attached (vmapped over candidates with the cache
        unbatched — no O(N·m) prefix recompute, no N× cache copy). The
        candidate position is excluded from L_q (n_skip=1), matching the
        reference scorer's exclusion of all prefix positions."""
        if not self.supports_kv_scoring:
            raise NotImplementedError(
                f"{self.cfg.family}: KV-reuse scoring unavailable; use "
                "cushioncache.greedy_search_ref")
        cfg = self.cfg
        valid = jnp.arange(prefix_kv["k"].shape[1]) < live_len

        def one(cand):
            nb = dict(batch)
            if cfg.family == Family.VLM:
                # candidate sits between the cushion and the patches
                ce = jnp.take(params["embed"]["w"], cand[None], axis=0)[None]
                ce = jnp.broadcast_to(ce, (batch["patches"].shape[0],)
                                      + ce.shape[1:])
                nb["patches"] = jnp.concatenate(
                    [ce.astype(batch["patches"].dtype), batch["patches"]],
                    axis=1)
            else:
                nb["tokens"] = jnp.concatenate(
                    [jnp.broadcast_to(cand[None, None],
                                      (batch["tokens"].shape[0], 1)),
                     batch["tokens"]], axis=1)
            _, taps = self.forward(params, nb, qcfg, scales=scales,
                                   cushion={"kv": prefix_kv}, collect=True,
                                   n_skip=1, prefix_valid=valid,
                                   pos_offset=live_len, remat=False)
            return TR.total_qerr(taps)

        return jax.vmap(one)(cand_ids)

    def extract_cushion(self, params, prefix_ids, batch,
                        qcfg: QuantConfig) -> Params:
        """Turn a searched token prefix into the deployment Cushion artifact
        (per-layer KV for attention archs; recurrent states for SSM/hybrid)
        by running the prefix through the model once (paper: 'their keys and
        values are cached and reused at inference', eq. 8)."""
        cfg = self.cfg
        m = int(prefix_ids.shape[0])
        toks = prefix_ids[None]
        if cfg.family == Family.SSM:
            _, _, states = XL.forward(params, toks, cfg, qcfg,
                                      return_cache=True, remat=False)
            return {"state": jax.tree_util.tree_map(lambda a: a[:, 0], states)}
        if cfg.family == Family.HYBRID:
            cache = HY.init_cache(cfg, 1, m)
            _, cache, _ = HY.prefill(params, toks, cache, cfg, qcfg)
            return {"kv": {"k": cache["k"][:, 0, :m], "v": cache["v"][:, 0, :m]},
                    "state": {"h": cache["h"][:, :, 0],
                              "conv": cache["conv"][:, :, 0]}}
        if cfg.family == Family.ENCDEC:
            # null acoustic context for the prefix pass (DESIGN.md §5)
            frames = jnp.zeros((1, cfg.encdec.encoder_seq, cfg.d_model),
                               C.dtype_of(cfg))
            cache = ED.init_cache(cfg, 1, m)
            _, cache, _ = ED.prefill(params, toks, cache, cfg, qcfg,
                                     frames=frames)
            return {"kv": {"k": cache["k"][:, 0, :m],
                           "v": cache["v"][:, 0, :m]}}
        mod = MO if cfg.family == Family.MOE else TR
        cache = mod.init_cache(cfg, 1, m)
        _, cache, _ = mod.prefill(params, toks, cache, cfg, qcfg)
        return {"kv": {"k": cache["k"][:, 0, :m], "v": cache["v"][:, 0, :m]}}

    # ------------------------------------------------------------------
    # Input construction
    # ------------------------------------------------------------------

    def make_batch(self, rng, batch: int, seq_len: int) -> Dict[str, Any]:
        """Concrete random batch (smoke tests / CPU experiments)."""
        cfg = self.cfg
        text_len = self.text_len(seq_len)
        toks = jax.random.randint(rng, (batch, text_len + 1), 0,
                                  cfg.vocab_size, dtype=jnp.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.family == Family.ENCDEC:
            out["frames"] = jax.random.normal(
                rng, (batch, cfg.encdec.encoder_seq, cfg.d_model),
                C.dtype_of(cfg)) * 0.02
        if cfg.family == Family.VLM:
            out["patches"] = jax.random.normal(
                rng, (batch, cfg.vlm.num_patches, cfg.d_model),
                C.dtype_of(cfg)) * 0.02
        return out

    def text_len(self, seq_len: int) -> int:
        """Token count such that total positions == seq_len."""
        if self.cfg.family == Family.VLM:
            return max(1, seq_len - self.cfg.vlm.num_patches)
        return seq_len

    def input_specs(self, batch: int, seq_len: int) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input (dry-run)."""
        cfg = self.cfg
        text_len = self.text_len(seq_len)
        sds = jax.ShapeDtypeStruct
        out = {"tokens": sds((batch, text_len), jnp.int32),
               "labels": sds((batch, text_len), jnp.int32)}
        if cfg.family == Family.ENCDEC:
            out["frames"] = sds((batch, cfg.encdec.encoder_seq, cfg.d_model),
                                C.dtype_of(cfg))
        if cfg.family == Family.VLM:
            out["patches"] = sds((batch, cfg.vlm.num_patches, cfg.d_model),
                                 C.dtype_of(cfg))
        return out


def build(cfg: ModelConfig) -> ModelAPI:
    return ModelAPI(cfg=cfg, mod=family_module(cfg))
