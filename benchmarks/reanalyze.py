"""Re-derive roofline terms for existing dry-run records from the archived
optimized-HLO (results/hlo/*.hlo.gz) — lets the HLO cost model iterate
without recompiling 64 cells.

    PYTHONPATH=src python -m benchmarks.reanalyze --in results/dryrun.jsonl
"""
from __future__ import annotations

import argparse
import gzip
import json
import os

from repro.launch.hlo_cost import analyze_hlo

PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW_PER_LINK = 50e9


def tag_of(r) -> str:
    return (f"{r['arch']}_{r['shape']}_{r['mesh']}_{r.get('quant','none')}"
            f"_m{r.get('cushion_m',0)}_{r.get('param_shard','fsdp')}"
            f"{'_pq' if r.get('prequant') else ''}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="results/dryrun.jsonl")
    ap.add_argument("--hlo-dir", default="results/hlo")
    args = ap.parse_args()
    rows = [json.loads(l) for l in open(args.inp)]
    out = []
    for r in rows:
        if not r.get("ok"):
            out.append(r)
            continue
        # records from before tags carried param_shard default to fsdp
        candidates = [tag_of(r),
                      f"{r['arch']}_{r['shape']}_{r['mesh']}"
                      f"_{r.get('quant','none')}_m{r.get('cushion_m',0)}"]
        path = None
        for c in candidates:
            p = os.path.join(args.hlo_dir, c + ".hlo.gz")
            if os.path.exists(p):
                path = p
                break
        if path is None:
            out.append(r)
            continue
        hlo = gzip.open(path, "rt").read()
        hc = analyze_hlo(hlo)
        r["flops_per_chip"] = hc["flops"]
        r["bytes_per_chip"] = hc["bytes"]
        r["collective_bytes_per_chip"] = hc["collective_bytes"]
        r["collective_counts"] = hc["collective_counts"]
        terms = {"compute_s": hc["flops"] / PEAK_FLOPS_BF16,
                 "memory_s": hc["bytes"] / HBM_BW,
                 "collective_s": hc["collective_bytes"] / ICI_BW_PER_LINK}
        r["terms"] = terms
        r["dominant"] = max(terms, key=lambda k: terms[k])
        if r.get("model_flops_per_chip") and hc["flops"]:
            r["useful_flops_frac"] = r["model_flops_per_chip"] / hc["flops"]
        out.append(r)
        print(f"[reanalyze] {tag_of(r)} mem={terms['memory_s']:.3g}s "
              f"coll={terms['collective_s']:.3g}s", flush=True)
    with open(args.inp, "w") as f:
        for r in out:
            f.write(json.dumps(r) + "\n")


if __name__ == "__main__":
    main()
