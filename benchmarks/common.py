"""Shared benchmark substrate: a trained paper_tiny model + an
outlier-planted variant (reproducing the paper's massive-activation
pathology deterministically at CPU scale), with cached artifacts so
re-running individual tables is fast.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import CheckpointManager
from repro.configs import (CushionConfig, QuantConfig, RunConfig, get_config)
from repro.core import cushioncache as CC
from repro.core.calibration import calibrate
from repro.data.pipeline import Pipeline, SyntheticCorpus
from repro.models.registry import build
from repro.train.trainer import (eval_next_token_acc, eval_ppl,
                                 make_optimizer, make_train_step)

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "paper")
TRAIN_STEPS = 250
SEQ = 128
BATCH = 8


class Bench:
    def __init__(self, train_steps: int = TRAIN_STEPS):
        self.cfg = get_config("paper_tiny")
        self.api = build(self.cfg)
        self.corpus = SyntheticCorpus(self.cfg.vocab_size, seed=0)
        self.pipe = Pipeline(self.corpus, batch=BATCH, seq_len=SEQ, seed=0)
        self.train_steps = train_steps
        self._params = None
        self._cushions: Dict[str, Any] = {}
        self._search_times: Dict[str, float] = {}
        os.makedirs(ART_DIR, exist_ok=True)
        self.ckpt = CheckpointManager(os.path.join(ART_DIR, "ckpt"), keep=1)

    # ------------------------------------------------------------------
    @property
    def params(self):
        if self._params is not None:
            return self._params
        like = self.api.init_params(jax.random.PRNGKey(0))
        if self.ckpt.latest_step() == self.train_steps:
            self._params = self.ckpt.restore(self.train_steps, like=like)
            return self._params
        run = RunConfig(model=self.cfg, seq_len=SEQ, global_batch=BATCH,
                        lr=2e-3, train_steps=self.train_steps,
                        warmup_steps=20)
        opt = make_optimizer(run)
        st = opt.init(like)
        step = jax.jit(make_train_step(self.api, run, opt))
        params = like
        for i in range(self.train_steps):
            b = {k: jnp.asarray(v) for k, v in self.pipe.get_batch(i).items()}
            params, st, m = step(params, st, b)
        self.ckpt.save(self.train_steps, params)
        self._params = params
        return params

    def planted(self):
        """Outlier-planted variant reproducing the paper's *attention-
        mediated* pathology (Bondarenko et al. 2023 mechanism):

        In layer 1, head 0's value path injects O(100) magnitudes into a
        block of channels for EVERY token; with near-uniform attention the
        attention output carries massive activations (Table-5-style
        10^2-10^3 : 1 top-1:median). A *sink* absorbs them: all head-0
        queries carry a constant bias direction q0, and token id 1's key is
        surgically aligned to kappa*q0 with its value projected out of the
        spike channels — attending to the sink yields ~zero value. So a
        prefix containing token 1 (or a tuned cushion KV playing the same
        role) collapses head-0 attention onto the sink and the outliers
        vanish — exactly the paper's Fig. 3 mechanism, planted
        deterministically at CPU scale.
        """
        import numpy as np
        params = jax.tree_util.tree_map(lambda a: a, self.params)
        cfg = self.cfg
        D, hd, H, K = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
        rng = np.random.RandomState(42)
        L = 1                       # plant in layer 1
        attn = dict(jax.tree_util.tree_map(lambda a: a,
                                           params["layers"]["attn"]))
        wqkv = np.asarray(attn["wqkv"]).copy()      # (L, D, (H+2K)*hd)
        bqkv = np.asarray(attn["bqkv"]).copy()

        # 1. spike V path of kv-head 0, channels 0:8: every token's value
        #    carries ~N(0, 120^2) in those channels
        voff = (H + K) * hd
        spike_cols = [voff + j for j in range(8)]
        spike_w = rng.choice([-1.0, 1.0], size=(D, 8)) * (600.0 / np.sqrt(D))
        wqkv[L][:, spike_cols] = spike_w

        # 1b. sharpen head-0 content attention (near-argmax): the spike
        #     lands coherently on each token instead of averaging out
        koff = H * hd
        wqkv[L][:, koff:koff + hd] *= 3.0

        # 1c. localize the spike into 8 residual channels: wo passes the
        #     spike o-channels straight through to channels 17..24 (this is
        #     what makes the pathology per-CHANNEL, like the paper's)
        wo = np.asarray(attn["wo"]).copy()          # (L, H*hd, D)
        tgt = list(range(17, 25))
        for g in range(H // K):
            rows = [g * hd + j for j in range(8)]   # q-heads sharing kv0
            wo[L][rows, :] = 0.0
            if g == 0:
                for rj, cj in zip(rows, tgt):
                    wo[L][rj, cj] = 1.0
        attn["wo"] = jnp.asarray(wo)

        # 1d. isolate: downstream layers don't read the spike channels, so
        #     the FP model's predictions survive the surgery (the paper's
        #     models carry massive activations without FP damage)
        for li in range(L + 1, cfg.n_layers):
            wqkv[li][tgt, :] = 0.0
        mlp_up = np.asarray(params["layers"]["mlp"]["w_up"]).copy()
        mlp_gate = np.asarray(params["layers"]["mlp"]["w_gate"]).copy()
        for li in range(L, cfg.n_layers):
            mlp_up[li][tgt, :] = 0.0
            mlp_gate[li][tgt, :] = 0.0
        head = np.asarray(params["head"]["w"]).copy()
        head[tgt, :] = 0.0

        # 2. sink-seeking query bias for all q-heads reading kv-head 0
        #    (GQA: q heads 0..H/K-1 share kv-head 0). q0 lives in the
        #    SLOWEST rotary pair so RoPE barely rotates it over the
        #    context (theta_min ~ 1e-4 rad/pos): the sink alignment is
        #    position-invariant, as in trained models.
        q0 = np.zeros(hd)
        q0[hd // 2 - 1] = 1.0 / np.sqrt(2)
        q0[hd - 1] = 1.0 / np.sqrt(2)
        for qh in range(H // K):
            bqkv[L][qh * hd:(qh + 1) * hd] = 6.0 * q0

        # 3. vocab sinks, aligned to the tokens' EMPIRICAL layer-1 hidden
        #    direction (embed + layer-0 output), computed by running the
        #    model itself:
        #    - token 1: a strong sink (kappa=100) for the greedy search to
        #      discover (the paper's <bos>-like nonsemantic sink)
        #    - the corpus' most frequent token: a weak sink (kappa=18), so
        #      positions AFTER its first occurrence have a natural place to
        #      dump attention — only the sequence head spikes, matching the
        #      first-token massive-activation phenomenon (Sun et al. 2024)
        emb = np.asarray(params["embed"]["w"]).copy()
        r = rng.randn(D).astype(np.float32) * 0.5
        emb[1] = r
        params_emb = dict(params)
        params_emb["embed"] = {"w": jnp.asarray(emb)}

        # most frequent corpus token (bigram stationary mode)
        cnt = np.bincount(np.concatenate(
            [self.pipe.get_batch(i)["tokens"].ravel() for i in range(4)]),
            minlength=cfg.vocab_size)
        cnt[1] = 0
        freq_tok = int(np.argmax(cnt))

        def layer1_dir(tok_id):
            """Empirical pre-norm layer-1 input direction for a token at
            position 0."""
            from repro.models import common as MC
            from repro.models import transformer as TT
            from repro.configs import QuantConfig as QC
            x = jnp.asarray(emb[tok_id])[None, None, :]
            lp0 = jax.tree_util.tree_map(lambda a: a[0], params["layers"])
            h, _ = TT._block(lp0, x, cfg, QC(mode="none"),
                             MC.placeholder_scales(TT.SITES, 1),
                             {"k": jnp.zeros((0, K, hd)),
                              "v": jnp.zeros((0, K, hd))},
                             jnp.arange(1), False, 0)
            hv = np.asarray(h)[0, 0]
            g = np.asarray(params["layers"]["ln1"]["g"][L])
            return hv / np.sqrt(np.mean(hv ** 2) + 1e-6) * g

        q0n = q0
        for tok_id, kappa in [(1, 100.0), (freq_tok, 18.0)]:
            rn = layer1_dir(tok_id)
            Wk0 = wqkv[L][:, koff:koff + hd]
            Wk0 += np.outer(rn / (rn @ rn), kappa * q0n - rn @ Wk0)
            wqkv[L][:, koff:koff + hd] = Wk0
            # sink value ~ 0 in spike channels
            cols = wqkv[L][:, spike_cols]
            cols -= np.outer(rn / (rn @ rn), rn @ cols)
            wqkv[L][:, spike_cols] = cols

        attn["wqkv"] = jnp.asarray(wqkv)
        attn["bqkv"] = jnp.asarray(bqkv)
        layers = dict(params["layers"])
        layers["attn"] = attn
        layers["mlp"] = dict(layers["mlp"])
        layers["mlp"]["w_up"] = jnp.asarray(mlp_up)
        layers["mlp"]["w_gate"] = jnp.asarray(mlp_gate)
        params = dict(params)
        params["layers"] = layers
        params["embed"] = {"w": jnp.asarray(emb)}
        params["head"] = {"w": jnp.asarray(head)}
        return params

    # ------------------------------------------------------------------
    def eval_batches(self, n=6):
        return [{k: jnp.asarray(v)
                 for k, v in self.pipe.get_batch(9000 + i).items()}
                for i in range(n)]

    def calib_batches(self, n=4):
        return [{k: jnp.asarray(v)
                 for k, v in self.pipe.get_batch(8000 + i).items()}
                for i in range(n)]

    def sample_fn(self, i):
        b = self.pipe.get_batch(5000 + i)
        return {"tokens": jnp.asarray(b["tokens"][:1]),
                "labels": jnp.asarray(b["labels"][:1])}

    def tune_iter(self):
        i = 0
        while True:
            b = self.pipe.get_batch(6000 + i)
            yield {k: jnp.asarray(v) for k, v in b.items()}
            i += 1

    # ------------------------------------------------------------------
    def cushion_for(self, params, key: str, qcfg: QuantConfig,
                    tune_steps: int = 60, skip_tune: bool = False):
        tag = f"{key}|{qcfg.mode}|{qcfg.a_bits}|{skip_tune}"
        if tag in self._cushions:
            return self._cushions[tag]
        # disk cache (re-running individual tables stays cheap)
        safe = tag.replace("|", "_").replace("=", "-")
        cpath = os.path.join(ART_DIR, "cushions", safe + ".npz")
        tpath = cpath + ".times.json"
        if os.path.exists(cpath):
            data = np.load(cpath)
            zero = self.api.cushion_zeros(int(data["prefix_len"]))
            flat, treedef = jax.tree_util.tree_flatten(zero)
            cushion = jax.tree_util.tree_unflatten(
                treedef, [jnp.asarray(data[f"a{i}"])
                          for i in range(len(flat))])
            if os.path.exists(tpath):
                self._search_times[tag] = json.load(open(tpath))
            self._cushions[tag] = cushion
            return cushion
        ccfg = CushionConfig(max_prefix_len=6, tau=0.98, n_candidates=48,
                             tune_steps=tune_steps, tune_lr=2e-2, lam=0.05,
                             seed_tokens=(1,))
        t0 = time.time()
        cushion, sr, tr = CC.discover(self.api, params, self.sample_fn,
                                      self.tune_iter(), qcfg, ccfg,
                                      jax.random.PRNGKey(7),
                                      skip_tune=skip_tune, verbose=False)
        self._search_times[tag] = {
            "search_s": sr.wall_time_s,
            "tune_s": tr.wall_time_s if tr else 0.0,
            "prefix_len": int(len(sr.prefix_ids))}
        self._cushions[tag] = cushion
        os.makedirs(os.path.join(ART_DIR, "cushions"), exist_ok=True)
        flat, _ = jax.tree_util.tree_flatten(cushion)
        m = (cushion["kv"]["k"].shape[1] if "kv" in cushion
             else len(sr.prefix_ids))
        np.savez(cpath, prefix_len=m,
                 **{f"a{i}": np.asarray(v) for i, v in enumerate(flat)})
        with open(tpath, "w") as f:
            json.dump(self._search_times[tag], f)
        return cushion

    def scales_for(self, params, qcfg: QuantConfig, cushion=None):
        scales, _ = calibrate(self.api, params, self.calib_batches(), qcfg,
                              cushion=cushion)
        return scales

    def ppl(self, params, qcfg, cushion=None, scales=None):
        return eval_ppl(self.api, params, self.eval_batches(), qcfg,
                        cushion=cushion, scales=scales)

    def acc(self, params, qcfg, cushion=None, scales=None):
        return eval_next_token_acc(self.api, params, self.eval_batches(),
                                   qcfg, cushion=cushion, scales=scales)


_BENCH: Optional[Bench] = None


def get_bench() -> Bench:
    global _BENCH
    if _BENCH is None:
        _BENCH = Bench()
    return _BENCH


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


def save_json(name: str, obj):
    os.makedirs(ART_DIR, exist_ok=True)
    with open(os.path.join(ART_DIR, name), "w") as f:
        json.dump(obj, f, indent=1, default=float)
