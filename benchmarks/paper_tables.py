"""One benchmark per paper table (Tables 1-6, 8, 9). Each returns a dict and
emits a CSV row `name,us_per_call,derived`. The subject model is the trained
paper_tiny plus the outlier-planted variant (paper-scale LLMs are not
loadable offline; DESIGN.md §7 documents the correspondence).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, get_bench, save_json
from repro.configs import QuantConfig
from repro.core import outliers
from repro.core.smoothquant import apply_smoothquant
from repro.core.calibration import calibrate
from repro.serving.engine import Engine

MODES = ["pt_static", "pt_dynamic", "ptoken_dynamic"]


def _grid_eval(b, params, modes, smooth: bool, cushion_tune=60):
    """ppl + acc for each mode x {bare, +cushion}."""
    out = {}
    p = params
    stats = None
    if smooth:
        _, stats = calibrate(b.api, params, b.calib_batches(),
                             QuantConfig(mode="pt_static"))
        p = apply_smoothquant(params, stats, b.cfg, alpha=0.8)
    for mode in modes:
        qcfg = QuantConfig(mode=mode, smoothquant=smooth)
        scales = b.scales_for(p, qcfg) if mode == "pt_static" else None
        out[(mode, "bare")] = {"ppl": b.ppl(p, qcfg, scales=scales),
                               "acc": b.acc(p, qcfg, scales=scales)}
        # discovery runs with on-the-fly (dynamic) scales — static scales
        # don't exist until the deployment config is fixed (paper §4.1)
        disc_q = (QuantConfig(mode="pt_dynamic", smoothquant=qcfg.smoothquant)
                  if mode == "pt_static" else qcfg)
        cush = b.cushion_for(p, f"smooth={smooth}", disc_q,
                             tune_steps=cushion_tune)
        cscales = (b.scales_for(p, qcfg, cushion=cush)
                   if mode == "pt_static" else None)
        out[(mode, "cushion")] = {
            "ppl": b.ppl(p, qcfg, cushion=cush, scales=cscales),
            "acc": b.acc(p, qcfg, cushion=cush, scales=cscales)}
    return out


def table1_2_w8a8():
    """Tables 1+2: W8A8 ppl / accuracy across granularities x smoothquant
    x ±CushionCache, on the outlier-planted model."""
    b = get_bench()
    t0 = time.time()
    params = b.planted()
    fp_ppl = b.ppl(params, QuantConfig(mode="none"))
    fp_acc = b.acc(params, QuantConfig(mode="none"))
    rows = {"fp16": {"ppl": fp_ppl, "acc": fp_acc}}
    for smooth in [False, True]:
        grid = _grid_eval(b, params, MODES, smooth)
        for (mode, var), v in grid.items():
            tag = f"{'sq+' if smooth else ''}{mode}{'+cc' if var == 'cushion' else ''}"
            rows[tag] = v
    dt = time.time() - t0
    save_json("table1_2.json", {str(k): v for k, v in rows.items()})
    static_gain = rows["pt_static"]["ppl"] / rows["pt_static+cc"]["ppl"]
    emit("table1_2_w8a8", dt * 1e6,
         f"static ppl {rows['pt_static']['ppl']:.2f}->"
         f"{rows['pt_static+cc']['ppl']:.2f} ({static_gain:.1f}x)")
    return rows


def table3_ablation():
    """Table 3: component ablation — greedy init / +prefix tuning /
    +quantization-aware loss (per-tensor dynamic, planted model)."""
    import copy
    from repro.configs import CushionConfig
    from repro.core import cushioncache as CC
    b = get_bench()
    t0 = time.time()
    params = b.planted()
    qcfg = QuantConfig(mode="pt_dynamic")
    rows = {"fp16": {"acc": b.acc(params, QuantConfig(mode="none"))},
            "pt_dynamic": {"acc": b.acc(params, qcfg)}}

    greedy = b.cushion_for(params, "ablate", qcfg, skip_tune=True)
    rows["+greedy_init"] = {"acc": b.acc(params, qcfg, cushion=greedy)}

    ccfg = CushionConfig(tune_steps=60, tune_lr=2e-2, lam=0.0)
    tr = CC.prefix_tune(b.api, params, greedy, b.tune_iter(), qcfg, ccfg,
                        verbose=False)
    rows["+prefix_tuning"] = {"acc": b.acc(params, qcfg, cushion=tr.cushion)}

    ccfg_q = CushionConfig(tune_steps=60, tune_lr=2e-2, lam=0.05)
    trq = CC.prefix_tune(b.api, params, greedy, b.tune_iter(), qcfg, ccfg_q,
                         verbose=False)
    rows["+quant_aware_loss"] = {"acc": b.acc(params, qcfg,
                                              cushion=trq.cushion)}
    dt = time.time() - t0
    save_json("table3.json", rows)
    emit("table3_ablation", dt * 1e6,
         f"acc {rows['pt_dynamic']['acc']:.3f}->"
         f"{rows['+quant_aware_loss']['acc']:.3f}")
    return rows


def table4_lowbit():
    """Table 4: W6A6 / W4A4 per-token dynamic ± CushionCache."""
    b = get_bench()
    t0 = time.time()
    params = b.planted()
    rows = {}
    for bits in [6, 4]:
        qcfg = QuantConfig(mode="ptoken_dynamic", w_bits=bits, a_bits=bits)
        rows[f"w{bits}a{bits}"] = {"ppl": b.ppl(params, qcfg),
                                   "acc": b.acc(params, qcfg)}
        cush = b.cushion_for(params, "lowbit", qcfg)
        rows[f"w{bits}a{bits}+cc"] = {
            "ppl": b.ppl(params, qcfg, cushion=cush),
            "acc": b.acc(params, qcfg, cushion=cush)}
    dt = time.time() - t0
    save_json("table4.json", rows)
    emit("table4_lowbit", dt * 1e6,
         f"w4a4 ppl {rows['w4a4']['ppl']:.2f}->{rows['w4a4+cc']['ppl']:.2f}")
    return rows


def table5_magnitudes():
    """Table 5 + Fig 2: activation-magnitude order statistics before/after
    CushionCache (planted model)."""
    b = get_bench()
    t0 = time.time()
    params = b.planted()
    qn = QuantConfig(mode="none")
    batch = b.eval_batches(1)[0]
    before = outliers.last_block_input_stats(b.api, params, batch, qn)
    cush = b.cushion_for(params, "mag", QuantConfig(mode="pt_dynamic"))
    after = outliers.last_block_input_stats(b.api, params, batch, qn,
                                            cushion=cush)
    per_layer_b = outliers.per_layer_top_stats(b.api, params, batch, qn)
    per_layer_a = outliers.per_layer_top_stats(b.api, params, batch, qn,
                                               cushion=cush)
    dt = time.time() - t0
    out = {"before": before, "after": after,
           "per_layer_before": per_layer_b, "per_layer_after": per_layer_a}
    save_json("table5.json", out)
    emit("table5_magnitudes", dt * 1e6,
         f"top1 {before['top1']:.1f}->{after['top1']:.1f} "
         f"median {before['median']:.3f}->{after['median']:.3f}")
    return out


def table6_walltime():
    """Table 6: wall-clock of greedy search (step 1) and prefix tuning
    (step 2)."""
    b = get_bench()
    t0 = time.time()
    params = b.planted()
    b.cushion_for(params, "walltime", QuantConfig(mode="pt_dynamic"))
    times = [v for k, v in b._search_times.items() if "walltime" in k]
    dt = time.time() - t0
    save_json("table6.json", times)
    t = times[0] if times else {"search_s": 0, "tune_s": 0}
    emit("table6_walltime", dt * 1e6,
         f"search {t['search_s']:.1f}s tune {t['tune_s']:.1f}s "
         f"len={t.get('prefix_len')}")
    return times


def table8_latency():
    """Table 8: TTFT / TPOT per quantization mode ± CushionCache (CPU
    timings — relative ordering is the claim, not absolute ms)."""
    b = get_bench()
    t0 = time.time()
    params = b.params
    batch = {k: v[:2, :64] for k, v in b.eval_batches(1)[0].items()}
    rows = {}
    for mode in ["pt_static", "pt_dynamic", "ptoken_dynamic"]:
        qcfg = QuantConfig(mode=mode)
        scales = b.scales_for(params, qcfg) if mode == "pt_static" else None
        disc_q = (QuantConfig(mode="pt_dynamic") if mode == "pt_static"
                  else qcfg)
        for cush_tag, cush in [("bare", None),
                               ("cc", b.cushion_for(params, "lat", disc_q))]:
            sc = scales
            if mode == "pt_static" and cush is not None:
                sc = b.scales_for(params, qcfg, cushion=cush)
            eng = Engine(b.api, params, qcfg, cushion=cush, scales=sc,
                         max_seq=256)
            res = eng.generate(batch, 16)
            res2 = eng.generate(batch, 16)    # warm
            rows[f"{mode}+{cush_tag}"] = {"ttft_ms": res2.ttft_ms,
                                          "tpot_ms": res2.tpot_ms}
    dt = time.time() - t0
    save_json("table8.json", rows)
    base = rows["pt_static+bare"]
    cc = rows["pt_static+cc"]
    emit("table8_latency", dt * 1e6,
         f"static TPOT {base['tpot_ms']:.1f}ms cc {cc['tpot_ms']:.1f}ms")
    return rows


def _quantize_kv_cache(cache, bits=2, group=32):
    """KIVI stand-in: group-wise asymmetric fake-quant of the KV cache."""
    def q(a):
        if a.ndim < 2:
            return a
        shp = a.shape
        d = shp[-1]
        g = group if d % group == 0 else d
        ar = a.reshape(*shp[:-1], d // g, g).astype(jnp.float32)
        mn = jnp.min(ar, axis=-1, keepdims=True)
        mx = jnp.max(ar, axis=-1, keepdims=True)
        qmax = 2 ** bits - 1
        scale = jnp.maximum((mx - mn) / qmax, 1e-8)
        aq = jnp.round((ar - mn) / scale)
        return (aq * scale + mn).reshape(shp).astype(a.dtype)
    return jax.tree_util.tree_map(q, cache)


def table9_combos():
    """Table 9: combination with other quantization methods — AWQ stand-in
    (weight-only W4 group quant), KIVI stand-in (2-bit KV cache quant)."""
    from repro.core import quantization as Q
    b = get_bench()
    t0 = time.time()
    params = b.planted()
    rows = {"fp16": {"ppl": b.ppl(params, QuantConfig(mode="none"))}}

    # AWQ stand-in: W4 group-128 weight-only
    w4 = QuantConfig(mode="none", w_bits=4, w_group=64)
    p_w4 = jax.tree_util.tree_map(lambda a: a, params)

    def quant_weights(tree):
        def visit(d):
            for k, v in list(d.items()):
                if isinstance(v, dict):
                    visit(d[k])
                elif k.startswith("w") and v.ndim >= 2:
                    d[k] = Q.weight_fake_quant(
                        v, QuantConfig(mode="pt_dynamic", w_bits=4,
                                       w_group=64))
        visit(tree)
        return tree
    p_w4 = quant_weights(p_w4)
    rows["awq_w4"] = {"ppl": b.ppl(p_w4, QuantConfig(mode="none"))}
    cush = b.cushion_for(params, "combo", QuantConfig(mode="pt_dynamic"))
    rows["awq_w4+cc"] = {"ppl": b.ppl(p_w4, QuantConfig(mode="none"),
                                      cushion=cush)}
    # AWQ + per-tensor static activations (the paper's "+Per-Cushion Static")
    qs = QuantConfig(mode="pt_static")
    rows["awq_w4+static"] = {"ppl": b.ppl(p_w4, qs,
                                          scales=b.scales_for(p_w4, qs))}
    rows["awq_w4+static+cc"] = {
        "ppl": b.ppl(p_w4, qs, cushion=cush,
                     scales=b.scales_for(p_w4, qs, cushion=cush))}

    # KIVI stand-in: decode with a 2-bit-quantized KV cache ± cushion
    def kv_acc(cushion):
        api = b.api
        batch = {k: v[:4, :48] for k, v in b.eval_batches(1)[0].items()}
        cache = api.init_cache(4, 96)
        lg, cache, pos = api.prefill(params, batch, cache,
                                     QuantConfig(mode="none"),
                                     cushion=cushion)
        cache = _quantize_kv_cache(cache, bits=2)
        correct = tot = 0
        toks = b.eval_batches(2)[1]["tokens"][:4, 48:64]
        labs = b.eval_batches(2)[1]["labels"][:4, 48:64]
        for i in range(8):
            lg, cache = api.decode_step(params, toks[:, i], pos, cache,
                                        QuantConfig(mode="none"))
            pos = pos + 1
            correct += float(jnp.sum(jnp.argmax(lg, -1) == labs[:, i]))
            tot += 4
        return correct / tot
    rows["kivi2"] = {"acc": kv_acc(None)}
    rows["kivi2+cc"] = {"acc": kv_acc(cush)}
    dt = time.time() - t0
    save_json("table9.json", rows)
    emit("table9_combos", dt * 1e6,
         f"awq ppl {rows['awq_w4']['ppl']:.2f} +cc "
         f"{rows['awq_w4+cc']['ppl']:.2f}")
    return rows


ALL = [table1_2_w8a8, table3_ablation, table4_lowbit, table5_magnitudes,
       table6_walltime, table8_latency, table9_combos]
