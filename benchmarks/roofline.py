"""Roofline reporter: reads the dry-run JSONL and emits the §Roofline table
(terms in seconds, dominant bottleneck, MODEL_FLOPS ratio, one-line fix
suggestion per cell).
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List

SUGGEST = {
    ("compute_s",): "increase per-chip batch or use int8 MXU (2x peak)",
    ("memory_s",): "cut HBM traffic: Pallas flash attention (keep P in "
                   "VMEM), fewer microbatches, fused quantized matmul",
    ("collective_s",): "reshard to cut all-gathers (SP residuals), overlap "
                       "collectives with compute, int8-compress DP grads",
}


def suggest(dom: str, rec: Dict) -> str:
    base = SUGGEST.get((dom,), "")
    if dom == "memory_s" and rec["kind"] == "decode":
        return "decode is weight/KV-streaming bound: quantize weights+KV " \
               "(W8A8 halves stream), batch more requests per chip"
    if dom == "collective_s" and rec.get("collective_counts", {}).get(
            "all-gather", 0) > 1000:
        return "per-microbatch FSDP weight all-gathers dominate: larger " \
               "microbatch + sequence-parallel activations"
    return base


def weight_stream_point(weight_bytes: Dict[str, int],
                        tpot_ms: Dict[str, float],
                        baseline: str = "fp") -> Dict[str, Dict[str, float]]:
    """Weight-streaming roofline point for quantized decode.

    Decode at batch ~1-8 is bound by streaming the resident weights from
    HBM once per token, so the bandwidth-bound model predicts a speedup
    over ``baseline`` equal to the resident-byte ratio (fp32 -> int8 = 4x,
    fp32 -> int4-packed = 8x). Pairs that prediction with the measured
    TPOT ratio per variant so bench JSON records predicted vs measured —
    CPU CI won't hit the HBM roof (the int dot is compute-limited there),
    but the byte ratios are the invariant the gate checks.

    weight_bytes / tpot_ms: variant name -> total resident bytes / measured
    per-token latency; both must contain ``baseline``.
    """
    base_b, base_t = weight_bytes[baseline], tpot_ms[baseline]
    out = {}
    for name, nbytes in weight_bytes.items():
        out[name] = {
            "resident_bytes": float(nbytes),
            "bytes_ratio_vs_%s" % baseline: nbytes / base_b,
            "predicted_decode_speedup": base_b / max(1, nbytes),
            "measured_decode_speedup": base_t / tpot_ms[name],
        }
    return out


def load(path: str) -> List[Dict]:
    rows = []
    with open(path) as f:
        for line in f:
            try:
                rows.append(json.loads(line))
            except json.JSONDecodeError:
                pass
    # keep the last record per cell key
    seen = {}
    for r in rows:
        key = (r.get("arch"), r.get("shape"), r.get("mesh"),
               r.get("quant", "none"), r.get("cushion_m", 0))
        seen[key] = r
    return list(seen.values())


def fmt_table(rows: List[Dict], mesh: str = "16x16",
              quant: str = "none") -> str:
    out = ["| arch | shape | compute s | memory s | collective s | "
           "dominant | model/HLO flops | bottleneck fix |",
           "|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r.get("arch", ""),
                                         r.get("shape", ""))):
        if r.get("mesh") != mesh or r.get("quant", "none") != quant:
            continue
        if not r.get("ok"):
            out.append(f"| {r.get('arch')} | {r.get('shape')} | FAILED: "
                       f"{r.get('error', '?')[:60]} | | | | | |")
            continue
        t = r["terms"]
        dom = r["dominant"].replace("_s", "")
        ratio = r.get("useful_flops_frac")
        out.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3g} | "
            f"{t['memory_s']:.3g} | {t['collective_s']:.3g} | {dom} | "
            f"{ratio:.2f} | {suggest(r['dominant'], r)[:70]} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="results/dryrun.jsonl")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--quant", default="none")
    args = ap.parse_args()
    rows = load(args.inp)
    print(fmt_table(rows, args.mesh, args.quant))
    ok = sum(1 for r in rows if r.get("ok"))
    print(f"\n{ok}/{len(rows)} cells OK")


if __name__ == "__main__":
    main()
