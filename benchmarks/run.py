"""Benchmark harness. One function per paper table (1-6, 8, 9) plus kernel
microbenches. Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run              # everything
    PYTHONPATH=src python -m benchmarks.run --only table5_magnitudes
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def kernel_microbench():
    """Per-call timings of the kernel oracles AND the real Pallas
    ``w8a8_matmul`` kernel (interpret mode on CPU, native on TPU) — the
    serving matmul path is bench-covered, not just test-covered. The
    kernel run is parity-checked against the oracle before its timing is
    emitted."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from benchmarks.common import emit
    from repro.kernels import ref as R
    from repro.kernels.w8a8_matmul import w8a8_matmul

    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randint(-127, 128, (512, 1024)), jnp.int8)
    w = jnp.asarray(rng.randint(-127, 128, (1024, 1024)), jnp.int8)
    f = jax.jit(lambda x, w: R.w8a8_matmul_ref(x, w, jnp.float32(0.01),
                                               jnp.float32(2.0),
                                               jnp.float32(0.02)))
    f(x, w).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        f(x, w).block_until_ready()
    emit("kernel_w8a8_ref_512x1024x1024",
         (time.perf_counter() - t0) / 10 * 1e6, "int8 matmul oracle")

    interpret = jax.default_backend() != "tpu"
    g = lambda x, w: w8a8_matmul(x, w, 0.01, 2.0, 0.02,
                                 interpret=interpret)
    out = g(x, w)
    out.block_until_ready()
    np.testing.assert_allclose(np.asarray(out), np.asarray(f(x, w)),
                               rtol=1e-6, atol=1e-5)
    t0 = time.perf_counter()
    for _ in range(10):
        g(x, w).block_until_ready()
    emit("kernel_w8a8_pallas_512x1024x1024",
         (time.perf_counter() - t0) / 10 * 1e6,
         f"Pallas kernel ({'interpret' if interpret else 'tpu'}), "
         f"parity-checked vs oracle")

    q = jnp.asarray(rng.randn(1, 8, 512, 64).astype(np.float32))
    k = jnp.asarray(rng.randn(1, 8, 528, 64).astype(np.float32))
    v = jnp.asarray(rng.randn(1, 8, 528, 64).astype(np.float32))
    g = jax.jit(lambda q, k, v: R.flash_attention_ref(q, k, v, True, 16))
    g(q, k, v).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(5):
        g(q, k, v).block_until_ready()
    emit("kernel_flash_ref_B1H8S512", (time.perf_counter() - t0) / 5 * 1e6,
         "prefix flash oracle")


def decode_bench():
    """Serving decode-path bench: TPOT at several cache fills, fp vs int8
    KV, scanned loop vs legacy per-token host loop. Emits CSV rows and the
    ``results/BENCH_decode.json`` trajectory artifact future PRs regress
    against."""
    import json
    import os

    import jax
    import jax.numpy as jnp
    import numpy as np
    from benchmarks.common import emit
    from repro.configs import QuantConfig, get_config
    from repro.models.registry import build
    from repro.serving.engine import Engine

    cfg = get_config("paper_tiny")
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    n_gen, B = 16, 2
    points = []
    for fill in (64, 192):
        rs = np.random.RandomState(fill)
        batch = {"tokens": jnp.asarray(
            rs.randint(0, cfg.vocab_size, (B, fill)), jnp.int32)}
        for kv_dtype in (None, "int8"):
            eng = Engine(api, params, QuantConfig(mode="none"),
                         max_seq=fill + n_gen + 8, kv_dtype=kv_dtype)
            eng.generate(batch, n_gen)            # warm/compile
            res = eng.generate(batch, n_gen)
            eng.generate_py(batch, n_gen)         # warm/compile
            res_py = eng.generate_py(batch, n_gen)
            tag = f"decode_fill{fill}_{kv_dtype or 'fp'}"
            emit(f"{tag}_tpot", res.tpot_ms * 1e3, "scanned decode loop")
            emit(f"{tag}_tpot_pyloop", res_py.tpot_ms * 1e3,
                 "per-token host-sync loop")
            points.append({"fill": fill, "kv_dtype": kv_dtype or "fp",
                           "batch": B, "n_gen": n_gen,
                           "ttft_ms": res.ttft_ms, "tpot_ms": res.tpot_ms,
                           "tpot_ms_pyloop": res_py.tpot_ms})
    out_dir = os.path.join(os.path.dirname(__file__), "..", "results")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "BENCH_decode.json"), "w") as f:
        json.dump({"bench": "decode", "points": points}, f, indent=1)


def search_bench():
    """Greedy-search fast-path bench: wall time + compile count of the
    compile-once KV-reuse search (`greedy_search`) vs the reference
    full-forward search (`greedy_search_ref`) on paper_tiny with planted
    outliers. Emits CSV rows and the ``results/BENCH_search.json``
    trajectory artifact future PRs regress against.

    Uses per-token dynamic activation quantization, where the two scorers
    are mathematically identical — the emitted ``prefix_match`` asserts the
    searched prefixes agree token for token."""
    import json
    import os

    import jax
    import numpy as np
    from benchmarks.common import emit
    from repro.configs import CushionConfig, QuantConfig, get_config
    from repro.core import cushioncache as CC
    from repro.models.registry import build
    from repro.monitoring import count_compiles

    cfg = get_config("paper_tiny")
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    # plant the paper's massive-activation pathology so candidate ranking
    # is meaningful (same surgery as tests/test_cushion.py)
    w = params["layers"]["mlp"]["w_down"]
    params["layers"]["mlp"]["w_down"] = w.at[0, :8, 5].set(300.0)

    qcfg = QuantConfig(mode="ptoken_dynamic")
    ccfg = CushionConfig(max_prefix_len=16, tau=1.5, n_candidates=64,
                         sample_len=48, seed_tokens=(1,))

    def sample(i):
        return api.make_batch(jax.random.PRNGKey(1000 + i), 1,
                              ccfg.sample_len)

    runs = {}
    for name, fn in (("fast", CC.greedy_search),
                     ("ref", CC.greedy_search_ref)):
        with count_compiles() as c:
            t0 = time.perf_counter()
            res = fn(api, params, sample, qcfg, ccfg, jax.random.PRNGKey(0),
                     chunk=8, verbose=False)
            wall = time.perf_counter() - t0
        runs[name] = {"wall_s": wall, "compiles": c.count,
                      "prefix": [int(t) for t in res.prefix_ids],
                      "iters": len(res.history)}
        emit(f"search_{name}_wall", wall * 1e6,
             f"{c.count} compiles, {len(res.history)} iters")

    speedup = runs["ref"]["wall_s"] / max(runs["fast"]["wall_s"], 1e-9)
    match = runs["fast"]["prefix"] == runs["ref"]["prefix"]
    emit("search_speedup", speedup * 1e6, f"prefix_match={match}")
    point = {"model": cfg.name, "quant_mode": qcfg.mode,
             "max_prefix_len": ccfg.max_prefix_len,
             "n_candidates": ccfg.n_candidates,
             "sample_len": ccfg.sample_len,
             "wall_s_fast": runs["fast"]["wall_s"],
             "wall_s_ref": runs["ref"]["wall_s"],
             "compiles_fast": runs["fast"]["compiles"],
             "compiles_ref": runs["ref"]["compiles"],
             "speedup": speedup, "prefix_match": match,
             "prefix_fast": runs["fast"]["prefix"],
             "prefix_ref": runs["ref"]["prefix"]}
    out_dir = os.path.join(os.path.dirname(__file__), "..", "results")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "BENCH_search.json"), "w") as f:
        json.dump({"bench": "search", "points": [point]}, f, indent=1)
    if not match:
        raise SystemExit(
            f"search fast path diverged from reference: "
            f"{runs['fast']['prefix']} vs {runs['ref']['prefix']}")


def serve_bench(tp: int = 1):
    """Continuous-batching serve bench: replay one Poisson-arrival trace
    through the slot-pool scheduler (``ContinuousEngine``) and through
    sequential per-request ``Engine.generate``, on paper_tiny with a
    cushion prefix. Asserts the cross-path parity oracle (greedy tokens
    identical request-for-request) and that continuous batching delivers
    higher aggregate tokens/s; emits CSV rows and the
    ``results/BENCH_serve.json`` trajectory artifact (tokens/s, p50/p99
    request latency, slot occupancy from ``monitoring.ServeStats``).

    ``tp > 1`` (``--tp``) reruns the whole bench on a (data=1, tp) mesh —
    params under the serve rules, KV pool sharded on its heads axis — and
    additionally asserts the sharded static Engine generates token-for-token
    what the unsharded one does; the point then lands in
    ``results/BENCH_tp.json`` so the tp trajectory regresses separately."""
    import json
    import os

    import jax
    import jax.numpy as jnp
    import numpy as np
    from benchmarks.common import emit
    from repro.configs import QuantConfig, get_config
    from repro.launch.serve import poisson_trace
    from repro.models.registry import build
    from repro.serving.engine import Engine
    from repro.serving.scheduler import ContinuousEngine

    mesh = None
    if tp > 1:
        from repro.launch.mesh import make_tp_mesh
        mesh = make_tp_mesh(tp)

    cfg = get_config("paper_tiny")
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    qcfg = QuantConfig(mode="none")
    cushion = api.extract_cushion(params, jnp.asarray([1, 2, 3], jnp.int32),
                                  None, qcfg)
    n_slots, n_requests, rate = 8, 16, 60.0
    prompt_lens, budgets = (48, 64), (32, 24)
    max_seq = 64 + 32 + 32
    reqs = poisson_trace(api, 0, n_requests, rate, prompt_lens, budgets)

    ce = ContinuousEngine(api, params, qcfg, n_slots=n_slots,
                          max_seq=max_seq, cushion=cushion, mesh=mesh)
    eng = Engine(api, params, qcfg, cushion=cushion, max_seq=max_seq,
                 mesh=mesh)

    if mesh is not None:
        # tp parity gate: the sharded engine must reproduce the unsharded
        # engine token-for-token before any throughput number is recorded
        eng1 = Engine(api, params, qcfg, cushion=cushion, max_seq=max_seq)
        r = reqs[0]
        if not np.array_equal(eng.generate(r.batch, r.max_new_tokens).tokens,
                              eng1.generate(r.batch, r.max_new_tokens).tokens):
            raise SystemExit(f"tp={tp} generation diverged from tp=1")
        del eng1

    first_arrival = min(r.arrival_s for r in reqs)

    def run_sequential():
        t0 = time.perf_counter()
        outs = []
        for r in sorted(reqs, key=lambda r: r.arrival_s):
            wait = r.arrival_s - (time.perf_counter() - t0)
            if wait > 0:            # requests can't start before they arrive
                time.sleep(wait)
            res = eng.generate(r.batch, r.max_new_tokens)
            outs.append((r, res, time.perf_counter() - t0))
        # span on the same basis as the continuous path: first arrival ->
        # last completion (excludes the idle lead-in before any work exists)
        span = outs[-1][2] - first_arrival
        lat = np.asarray([done - r.arrival_s for r, _, done in outs])
        return outs, span, lat

    # warm both paths: the bench measures steady-state serving, not tracing
    ce.run(reqs)
    run_sequential()

    cont = ce.run(reqs)
    span_c = max(o.finished_s for o in cont) - first_arrival
    lat_c = np.asarray([o.latency_s for o in cont])
    total = sum(len(o.tokens) for o in cont)
    tps_c = total / span_c

    seq, span_s, lat_s = run_sequential()
    tps_s = total / span_s

    # poisson_trace emits uids in arrival order, so seq[i] is request uid i
    match = all(o.uid == r.uid and np.array_equal(o.tokens, res.tokens[0])
                for o, (r, res, _) in zip(cont, seq))
    occ = ce.stats.occupancy()
    emit("serve_continuous_tokens_per_s", tps_c * 1e6,
         f"{n_slots} slots, occupancy={occ:.2f}")
    emit("serve_sequential_tokens_per_s", tps_s * 1e6,
         "per-request Engine.generate")
    emit("serve_speedup", tps_c / tps_s * 1e6, f"parity_match={match}")

    point = {"model": cfg.name, "tp": tp, "n_slots": n_slots,
             "n_requests": n_requests, "rate_req_s": rate,
             "prompt_lens": list(prompt_lens), "budgets": list(budgets),
             "total_tokens": total,
             "tokens_per_s_continuous": tps_c,
             "tokens_per_s_sequential": tps_s,
             "speedup": tps_c / tps_s,
             "p50_latency_s_continuous": float(np.percentile(lat_c, 50)),
             "p99_latency_s_continuous": float(np.percentile(lat_c, 99)),
             "p50_latency_s_sequential": float(np.percentile(lat_s, 50)),
             "p99_latency_s_sequential": float(np.percentile(lat_s, 99)),
             "parity_match": match, **ce.stats.as_dict()}
    out_dir = os.path.join(os.path.dirname(__file__), "..", "results")
    os.makedirs(out_dir, exist_ok=True)
    fname, bname = (("BENCH_tp.json", "serve_tp") if tp > 1
                    else ("BENCH_serve.json", "serve"))
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump({"bench": bname, "points": [point]}, f, indent=1)
    if not match:
        raise SystemExit("continuous scheduler diverged from per-request "
                         "Engine.generate (parity oracle failed)")
    if tps_c <= tps_s:
        raise SystemExit(
            f"continuous batching did not beat sequential serving: "
            f"{tps_c:.1f} vs {tps_s:.1f} tok/s")

    if tp == 1:
        # ------------------------------------------------------------------
        # Chunked-prefill head-of-line point: one long prompt arrives first
        # on a high-rate Poisson trace of short interactive requests. With
        # blocking admission every short behind the long waits out its whole
        # prefill; with chunked admission shorts admit between the long's
        # chunks. Gate: p99 arrival->first-token TTFT of the short class
        # strictly below the blocking baseline, at token-for-token parity.
        # ------------------------------------------------------------------
        long_len, short_len, n_long = 448, 32, 2
        lens = ((long_len,) + (short_len,) * 7) * n_long
        reqs2 = poisson_trace(api, 1, len(lens), 200.0, lens, (8,))
        arrivals = {r.uid: r.arrival_s for r in reqs2}
        short_uids = {r.uid for r in reqs2
                      if r.batch["tokens"].shape[1] == short_len}

        def arrival_ttft(outs, uids):
            return np.asarray([o.admitted_s - arrivals[o.uid]
                               for o in outs if o.uid in uids])

        kw = dict(n_slots=16, max_seq=512, cushion=cushion)
        blocking = ContinuousEngine(api, params, qcfg, **kw)
        chunked = ContinuousEngine(api, params, qcfg, chunk_tokens=64, **kw)
        blocking.run(reqs2)         # warm/compile (incl. per-chunk shapes)
        chunked.run(reqs2)
        out_b = blocking.run(reqs2)
        out_c = chunked.run(reqs2)
        match2 = all(a.uid == b.uid and np.array_equal(a.tokens, b.tokens)
                     for a, b in zip(out_b, out_c))
        p99_b = float(np.percentile(arrival_ttft(out_b, short_uids), 99))
        p99_c = float(np.percentile(arrival_ttft(out_c, short_uids), 99))
        all_b = arrival_ttft(out_b, arrivals)
        all_c = arrival_ttft(out_c, arrivals)
        emit("serve_chunked_p99_ttft_short_blocking", p99_b * 1e6,
             f"{n_long}x{long_len}-tok long prompt ahead")
        emit("serve_chunked_p99_ttft_short_chunked", p99_c * 1e6,
             f"chunk=64, {chunked.stats.prefill_chunks} chunks, "
             f"parity={match2}")
        point2 = {"model": cfg.name, "tp": tp, "mode": "chunked_prefill",
                  "n_slots": 16, "n_requests": len(lens),
                  "rate_req_s": 200.0, "chunk_tokens": 64,
                  "long_prompt_len": long_len, "short_prompt_len": short_len,
                  "n_long": n_long, "parity_match": match2,
                  "prefill_chunks": chunked.stats.prefill_chunks,
                  "p99_ttft_s_short_blocking": p99_b,
                  "p99_ttft_s_short_chunked": p99_c,
                  "p50_ttft_s_all_blocking": float(np.percentile(all_b, 50)),
                  "p99_ttft_s_all_blocking": float(np.percentile(all_b, 99)),
                  "p50_ttft_s_all_chunked": float(np.percentile(all_c, 50)),
                  "p99_ttft_s_all_chunked": float(np.percentile(all_c, 99))}
        with open(os.path.join(out_dir, "BENCH_serve.json")) as f:
            doc = json.load(f)
        doc["points"].append(point2)
        with open(os.path.join(out_dir, "BENCH_serve.json"), "w") as f:
            json.dump(doc, f, indent=1)
        if not match2:
            raise SystemExit("chunked admission diverged from blocking "
                             "admission (parity oracle failed)")
        if p99_c >= p99_b:
            raise SystemExit(
                f"chunked prefill did not beat blocking admission on "
                f"short-request p99 TTFT: {p99_c * 1e3:.1f}ms vs "
                f"{p99_b * 1e3:.1f}ms (head-of-line block not relieved)")


def w8a8_bench():
    """Calibrated W8A8 serving bench: fp vs per-tensor-static int8 serving
    TTFT/TPOT on one paper_tiny trace, parity-gated. Three engines share
    one calibration: the fp baseline (mode=none), the fp-weight true-int8
    pt_static path (weights quantized on the fly inside the jit), and the
    int8-resident prequantized path (--prequant; decode streams
    1 byte/weight). The gate asserts prequantized greedy tokens equal the
    fp-weight pt_static tokens bit-for-bit — identical int math, only the
    weight residency differs — before any number lands in the checked-in
    ``results/BENCH_w8a8.json`` trajectory."""
    import json
    import os

    import jax
    import numpy as np
    from benchmarks.common import emit
    from repro.configs import QuantConfig, get_config
    from repro.core.calibration import calibrate
    from repro.models.registry import build
    from repro.serving.engine import Engine

    cfg = get_config("paper_tiny")
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    qfp = QuantConfig(mode="none")
    qw8 = QuantConfig(mode="pt_static", true_int8=True)
    cal = [api.make_batch(jax.random.PRNGKey(100 + i), 2, 48)
           for i in range(2)]
    scales, _ = calibrate(api, params, cal, qw8)
    B, prompt, n_gen = 4, 64, 32
    batch = api.make_batch(jax.random.PRNGKey(7), B, prompt)
    max_seq = prompt + n_gen + 32

    engines = {
        "fp": Engine(api, params, qfp, max_seq=max_seq),
        "w8a8": Engine(api, params, qw8, max_seq=max_seq, scales=scales),
        "w8a8_prequant": Engine(api, params, qw8, max_seq=max_seq,
                                scales=scales, prequant=True),
    }
    results = {}
    for name, eng in engines.items():
        eng.generate(batch, n_gen)          # warm/compile pass
        res = eng.generate(batch, n_gen)
        results[name] = res
        emit(f"w8a8_{name}_ttft", res.ttft_ms * 1e3, "prefill wall")
        emit(f"w8a8_{name}_tpot", res.tpot_ms * 1e3, "per-token wall")

    match = bool(np.array_equal(results["w8a8_prequant"].tokens,
                                results["w8a8"].tokens))
    emit("w8a8_parity", float(match) * 1e6,
         "prequant tokens == fp-weight pt_static tokens")
    ttft_ratio = results["w8a8_prequant"].ttft_ms / results["fp"].ttft_ms
    emit("w8a8_prequant_ttft_ratio", ttft_ratio * 1e6, "prequant/fp TTFT")
    point = {"model": cfg.name, "batch": B, "prompt_len": prompt,
             "n_gen": n_gen, "parity_match": match,
             "ttft_ratio_prequant_vs_fp": ttft_ratio,
             "weight_bytes_fp": engines["fp"].weight_bytes_fp,
             "weight_bytes_int8_resident":
                 engines["w8a8_prequant"].weight_bytes_int8}
    for name, res in results.items():
        point[f"ttft_ms_{name}"] = res.ttft_ms
        point[f"tpot_ms_{name}"] = res.tpot_ms
    out_dir = os.path.join(os.path.dirname(__file__), "..", "results")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "BENCH_w8a8.json"), "w") as f:
        json.dump({"bench": "w8a8", "points": [point]}, f, indent=1)
    if not match:
        raise SystemExit(
            "int8-resident (prequantized) serving diverged from the "
            "fp-weight pt_static path (parity oracle failed)")
    # TTFT regression gate: prequantized prefill once ran ~3.9x fp on this
    # bench (CPU int8 dot_general scalarizes; the kernel path padded ragged
    # M to the tile). With the tiled ragged-M kernel and the exact f32-GEMM
    # CPU product, prefill must stay in the same ballpark as fp. The 1.5x
    # bound leaves room for quantize/dequant overhead but fails the bench
    # if pad-to-max (or the scalarized int8 product) ever comes back.
    if ttft_ratio > 1.5:
        raise SystemExit(
            f"prequantized TTFT regression: {ttft_ratio:.2f}x fp "
            f"({results['w8a8_prequant'].ttft_ms:.1f}ms vs "
            f"{results['fp'].ttft_ms:.1f}ms), gate is 1.5x")


def w4a8_bench(tp: int = 1):
    """W4A8 serving bench (``results/BENCH_w4a8.json``): int4-packed
    resident weights under the cushion prefix, gated four ways before any
    number lands in the trajectory:

    * route parity — the Pallas unpack-in-VMEM kernel (interpret mode off
      TPU) and the exact jnp fallback must generate greedy tokens
      token-for-token identical from the same packed tree;
    * residency — int4-packed bytes must be <= 0.55x the int8-resident
      W8A8 bytes (the 2x pack, with headroom for group scales);
    * TTFT — prequantized W4A8 prefill <= 1.5x fp (same regression gate
      as w8a8_bench: pad-to-max or a scalarized product would blow this);
    * quality under the cushion — greedy top-1 agreement vs fp and 4-bit
      fake-quant qerr, cushioned vs uncushioned (each calibrated under its
      own deployment distribution): the cushion must not lose top-1
      agreement and must reduce qerr, on the planted-outlier paper_tiny
      (same ``w_down`` surgery as cushion_bench).

    ``tp > 1`` (``--tp``) additionally asserts the sharded packed tree
    (serve rules; packed K-axis replicated) generates token-for-token what
    the unsharded engine does. The point records the weight-streaming
    roofline (predicted vs measured decode speedup from resident-byte
    ratios, ``benchmarks.roofline.weight_stream_point``)."""
    import json
    import os

    import jax
    import jax.numpy as jnp
    import numpy as np
    from benchmarks.common import emit
    from benchmarks.roofline import weight_stream_point
    from repro import flags
    from repro.configs import QuantConfig, get_config
    from repro.core import quantization as Q
    from repro.core.calibration import calibrate
    from repro.models import transformer as TMOD
    from repro.models.registry import build
    from repro.serving.engine import Engine

    mesh = None
    if tp > 1:
        from repro.launch.mesh import make_tp_mesh
        mesh = make_tp_mesh(tp)

    cfg = get_config("paper_tiny")
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    # plant the massive-activation pathway the cushion mitigates (same
    # surgery as cushion_bench) so the quality A/B measures the paper's
    # mechanism, not random-init noise
    w = params["layers"]["mlp"]["w_down"]
    params["layers"]["mlp"]["w_down"] = w.at[0, :8, 5].set(300.0)

    qfp = QuantConfig(mode="none")
    qw = QuantConfig(mode="pt_static", true_int8=True)
    cushion = api.extract_cushion(params, jnp.asarray([1, 2, 3], jnp.int32),
                                  None, qfp)
    cal = [api.make_batch(jax.random.PRNGKey(100 + i), 2, 48)
           for i in range(2)]
    scales, _ = calibrate(api, params, cal, qw, cushion=cushion)
    B, prompt, n_gen = 4, 64, 32
    batch = api.make_batch(jax.random.PRNGKey(7), B, prompt)
    max_seq = prompt + n_gen + 32

    def quant_engine(**kw):
        return Engine(api, params, qw, max_seq=max_seq, cushion=cushion,
                      scales=scales, prequant=True, **kw)

    engines = {
        "fp": Engine(api, params, qfp, max_seq=max_seq, cushion=cushion),
        "w8a8": quant_engine(),
        "w4a8": quant_engine(weight_bits=4),
    }
    results, ttft_ms, tpot_ms = {}, {}, {}
    for name, eng in engines.items():
        eng.generate(batch, n_gen)          # warm/compile pass
        runs = [eng.generate(batch, n_gen) for _ in range(3)]
        results[name] = runs[-1]
        # best-of-3 wall times: the TTFT regression gate compares two
        # ~20ms CPU prefills, so a single scheduler hiccup would flake it
        ttft_ms[name] = min(r.ttft_ms for r in runs)
        tpot_ms[name] = min(r.tpot_ms for r in runs)
        emit(f"w4a8_{name}_ttft", ttft_ms[name] * 1e3, "prefill wall")
        emit(f"w4a8_{name}_tpot", tpot_ms[name] * 1e3, "per-token wall")

    # route parity: jnp fallback vs Pallas kernel on the same packed tree.
    # Off TPU the kernel runs in interpret mode, so this gate exercises the
    # real kernel body (nibble unpack, group-scale accumulate, colsum
    # epilogue) on every CI run.
    old_route = flags.W4A8_KERNEL
    try:
        flags.W4A8_KERNEL = "jnp"
        toks_jnp = quant_engine(weight_bits=4).generate(batch, n_gen).tokens
        flags.W4A8_KERNEL = "pallas"
        toks_pal = quant_engine(weight_bits=4).generate(batch, n_gen).tokens
    finally:
        flags.W4A8_KERNEL = old_route
    route_match = bool(np.array_equal(toks_jnp, toks_pal))
    emit("w4a8_route_parity", float(route_match) * 1e6,
         "pallas kernel tokens == jnp fallback tokens")

    # quality under the cushion: teacher-forced greedy top-1 agreement vs
    # fp, and the paper's 4-bit fake-quant qerr, each A/B'd against the
    # uncushioned deployment (calibrated without the cushion)
    eval_batches = [api.make_batch(jax.random.PRNGKey(7000 + i), 2, 48)
                    for i in range(4)]
    qd4 = QuantConfig(mode="pt_dynamic", w_bits=4)

    def quality(c):
        sc, _ = calibrate(api, params, cal, qw, cushion=c)
        pq = Q.prequantize_tree(params, qw, weight_bits=4)
        tot = hit = 0
        for b in eval_batches:
            lf, _ = api.forward(params, b, qfp, cushion=c)
            lq, _ = api.forward(pq, b, qw, cushion=c, scales=sc)
            tot += lf.shape[0] * lf.shape[1]
            hit += int((jnp.argmax(lf, -1) == jnp.argmax(lq, -1)).sum())
        _, taps = api.forward(params, eval_batches[0], qd4, cushion=c,
                              collect=True)
        return hit / tot, float(TMOD.total_qerr(taps))

    agree_c, qerr_c = quality(cushion)
    agree_n, qerr_n = quality(None)
    emit("w4a8_top1_vs_fp_cushion", agree_c * 1e6,
         f"uncushioned={agree_n:.4f}")
    emit("w4a8_qerr4_cushion", qerr_c * 1e3, f"uncushioned={qerr_n:.2f}")

    tp_match = None
    if mesh is not None:
        eng_tp = quant_engine(weight_bits=4, mesh=mesh)
        tp_match = bool(np.array_equal(eng_tp.generate(batch, n_gen).tokens,
                                       results["w4a8"].tokens))
        emit("w4a8_tp_parity", float(tp_match) * 1e6,
             f"tp={tp} packed-tree tokens == unsharded tokens")

    e4, e8, efp = engines["w4a8"], engines["w8a8"], engines["fp"]
    bytes_ratio = e4.weight_bytes_int4 / e8.weight_bytes_int8
    ttft_ratio = ttft_ms["w4a8"] / ttft_ms["fp"]
    emit("w4a8_bytes_ratio_vs_int8", bytes_ratio * 1e6, "packed/int8 bytes")
    emit("w4a8_prequant_ttft_ratio", ttft_ratio * 1e6, "w4a8/fp TTFT")

    roofline = weight_stream_point(
        {"fp": efp.weight_bytes_fp,
         "w8a8": e8.weight_bytes_fp + e8.weight_bytes_int8,
         "w4a8": e4.weight_bytes_fp + e4.weight_bytes_int4},
        dict(tpot_ms))

    point = {"model": cfg.name, "tp": tp, "batch": B, "prompt_len": prompt,
             "n_gen": n_gen, "group_size": qw.w_group,
             "route_parity_match": route_match, "tp_parity_match": tp_match,
             "bytes_ratio_int4_vs_int8": bytes_ratio,
             "ttft_ratio_prequant_vs_fp": ttft_ratio,
             "weight_bytes_fp": efp.weight_bytes_fp,
             "weight_bytes_int8_resident": e8.weight_bytes_int8,
             "weight_bytes_int4_resident": e4.weight_bytes_int4,
             "top1_vs_fp": {"cushion": agree_c, "none": agree_n},
             "qerr_w4_fakequant": {"cushion": qerr_c, "none": qerr_n},
             "roofline": roofline}
    for name in results:
        point[f"ttft_ms_{name}"] = ttft_ms[name]
        point[f"tpot_ms_{name}"] = tpot_ms[name]
    out_dir = os.path.join(os.path.dirname(__file__), "..", "results")
    os.makedirs(out_dir, exist_ok=True)
    fname = "BENCH_w4a8.json" if tp == 1 else "BENCH_w4a8_tp.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump({"bench": "w4a8", "points": [point]}, f, indent=1,
                  default=float)

    if not route_match:
        raise SystemExit("w4a8 Pallas kernel diverged from the exact jnp "
                         "fallback on the same packed tree (route parity "
                         "oracle failed)")
    if tp_match is False:
        raise SystemExit(f"tp={tp} sharded packed tree diverged from the "
                         f"unsharded w4a8 engine (tp parity oracle failed)")
    if bytes_ratio > 0.55:
        raise SystemExit(f"int4-packed residency regression: packed bytes "
                         f"are {bytes_ratio:.2f}x the int8-resident bytes, "
                         f"gate is 0.55x")
    # the TTFT regression gate is a single-process CPU wall-time bound;
    # under --tp the forced host-device split divides the XLA thread pool
    # and penalizes the heavier unpack prefill disproportionately, so the
    # tp run gates parity only and the dense run owns the perf gate
    if tp == 1 and ttft_ratio > 1.5:
        raise SystemExit(
            f"w4a8 prequantized TTFT regression: {ttft_ratio:.2f}x fp "
            f"({ttft_ms['w4a8']:.1f}ms vs {ttft_ms['fp']:.1f}ms), "
            f"gate is 1.5x")
    if agree_c < agree_n:
        raise SystemExit(f"cushion lost w4a8 greedy top-1 agreement vs fp: "
                         f"{agree_c:.4f} cushioned vs {agree_n:.4f} "
                         f"uncushioned")
    if qerr_c >= qerr_n:
        raise SystemExit(f"cushion does not reduce 4-bit quantization "
                         f"error: {qerr_c:.2f} vs {qerr_n:.2f} uncushioned")


def router_bench(replicas: int = 2):
    """Fault-tolerant replica-router bench: one Poisson trace through
    ``ReplicaRouter`` twice — a no-fault run, then the same trace with a
    deterministic chaos kill of one replica mid-trace
    (``crash@replica1.step``). The parity gate asserts the chaos run
    completes every request with greedy tokens token-for-token identical
    to the no-fault run (the cushion prefix is replicated bit-identically
    on every replica, and greedy decode is batch-composition independent,
    so failover retries are exact); retries/failovers/deaths must be
    visible in RouterStats. Emits CSV rows and the checked-in
    ``results/BENCH_router.json`` artifact with p50/p99 latency and TTFT
    for both runs."""
    import json
    import os

    import jax
    import jax.numpy as jnp
    import numpy as np
    from benchmarks.common import emit
    from repro.configs import QuantConfig, get_config
    from repro.distributed.fault_injection import FailPoint, FaultInjector
    from repro.launch.serve import poisson_trace
    from repro.models.registry import build
    from repro.serving.router import ReplicaRouter, RouterConfig

    cfg = get_config("paper_tiny")
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    qcfg = QuantConfig(mode="none")
    cushion = api.extract_cushion(params, jnp.asarray([1, 2, 3], jnp.int32),
                                  None, qcfg)
    n_slots, n_requests, rate = 4, 16, 60.0
    reqs = poisson_trace(api, 0, n_requests, rate,
                         prompt_lens=(48, 64), budgets=(32, 24))
    router = ReplicaRouter(api, params, qcfg, n_replicas=replicas,
                           cfg=RouterConfig(max_queue=n_requests),
                           cushion=cushion, n_slots=n_slots,
                           max_seq=64 + 32 + 32)

    router.run(reqs)                    # warm/compile pass
    base = router.run(reqs)             # no-fault measured run
    kill = FaultInjector([FailPoint(site="replica1.step", kind="crash",
                                    at_step=6)])
    chaos = router.run(reqs, injector=kill)

    def _pcts(res):
        lat = np.asarray([o.latency_s for o in res.outputs])
        ttft = np.asarray([o.ttft_ms for o in res.outputs])
        return {"p50_latency_s": float(np.percentile(lat, 50)),
                "p99_latency_s": float(np.percentile(lat, 99)),
                "p50_ttft_ms": float(np.percentile(ttft, 50)),
                "p99_ttft_ms": float(np.percentile(ttft, 99))}

    want = {o.uid: o.tokens for o in base.outputs}
    match = (len(base.outputs) == n_requests == len(chaos.outputs)
             and not base.rejected and not chaos.rejected
             and all(np.array_equal(o.tokens, want[o.uid])
                     for o in chaos.outputs))
    cs = chaos.stats
    fault_visible = (cs.replica_deaths == 1 and cs.failovers >= 1
                     and cs.retries >= 1)
    bp, cp = _pcts(base), _pcts(chaos)
    emit("router_nofault_p50_latency", bp["p50_latency_s"] * 1e6,
         f"{replicas} replicas x {n_slots} slots")
    emit("router_chaos_p50_latency", cp["p50_latency_s"] * 1e6,
         f"kill replica1 mid-trace; deaths={cs.replica_deaths} "
         f"failovers={cs.failovers} retries={cs.retries}")
    emit("router_parity", float(match) * 1e6,
         "chaos tokens == no-fault tokens for every request")

    point = {"model": cfg.name, "replicas": replicas, "n_slots": n_slots,
             "n_requests": n_requests, "rate_req_s": rate,
             "parity_match": match, "fault_visible": fault_visible,
             "nofault": {**bp, **base.stats.as_dict()},
             "chaos": {"kill": "crash@replica1.step:6", **cp,
                       **cs.as_dict()}}
    out_dir = os.path.join(os.path.dirname(__file__), "..", "results")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "BENCH_router.json"), "w") as f:
        json.dump({"bench": "router", "points": [point]}, f, indent=1)
    if not match:
        raise SystemExit("chaos run diverged from no-fault run "
                         "(router failover parity oracle failed)")
    if not fault_visible:
        raise SystemExit(
            f"injected kill left no trace in RouterStats: deaths="
            f"{cs.replica_deaths} failovers={cs.failovers} "
            f"retries={cs.retries}")


def page_bench(tp: int = 1):
    """Paged-KV-pool bench (``serving/paging.py``): one Poisson trace on
    paper_tiny with a cushion prefix, served by the dense per-slot pool and
    by the paged pool at matched ``n_slots``/``max_seq``. Parity-gated on
    four axes before anything lands in ``results/BENCH_pages.json``:

    * token-for-token identity paged vs contiguous on the same seeded trace
    * pool bytes reduced >= 2x at matched slots (the page store + tables +
      batch-free cushion vs the dense rows)
    * higher sustainable ``n_slots`` at fixed memory: a 2x-slot paged pool
      fitting inside the dense pool's byte budget serves the same trace
      token-for-token (greedy decode is batch-composition independent)
    * prefix caching: a stem-sharing trace hits the content-addressed page
      registry (hits >= 1) and still matches the dense pool token-for-token

    tokens/s for both pools is recorded and gated to "within noise or
    better" (paged >= 0.8x contiguous on this CPU-scale model; the win is
    memory, the gate guards against a pathological slowdown). ``tp > 1``
    (``--tp``) additionally runs the paged pool on a (data=1, tp) mesh —
    pages sharded on the heads axis — and gates its tokens against the
    unsharded dense run, landing ``tp_parity`` in the same artifact."""
    import json
    import os

    import jax
    import jax.numpy as jnp
    import numpy as np
    from benchmarks.common import emit
    from repro.configs import QuantConfig, get_config
    from repro.launch.serve import poisson_trace
    from repro.models.registry import build
    from repro.serving.scheduler import ContinuousEngine

    cfg = get_config("paper_tiny")
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    qcfg = QuantConfig(mode="none")
    cushion = api.extract_cushion(params, jnp.asarray([1, 2, 3], jnp.int32),
                                  None, qcfg)
    n_slots, n_requests, rate = 8, 16, 60.0
    prompt_lens, budgets = (48, 64), (32, 24)
    max_seq, ps = 384, 32
    # worst case here is 3 content pages per slot (prompt 64 + budget 24
    # under a 3-token cushion); 36 pages hold every slot's worst case with
    # headroom while the dense pool must provision 8 * 384 positions
    n_pages = 36
    reqs = poisson_trace(api, 0, n_requests, rate, prompt_lens, budgets)

    def run_engine(eng):
        eng.run(reqs)                       # warm/compile pass
        outs = eng.run(reqs)
        span = (max(o.finished_s for o in outs)
                - min(r.arrival_s for r in reqs))
        total = sum(len(o.tokens) for o in outs)
        return outs, total / span

    dense = ContinuousEngine(api, params, qcfg, n_slots=n_slots,
                             max_seq=max_seq, cushion=cushion)
    outs_d, tps_d = run_engine(dense)
    bytes_d = dense.stats.pool_bytes

    paged = ContinuousEngine(api, params, qcfg, n_slots=n_slots,
                             max_seq=max_seq, cushion=cushion, paged=True,
                             page_size=ps, n_pages=n_pages)
    outs_p, tps_p = run_engine(paged)
    bytes_p = paged.stats.pool_bytes

    want = {o.uid: o.tokens for o in outs_d}
    match = (len(outs_d) == n_requests == len(outs_p)
             and all(np.array_equal(o.tokens, want[o.uid])
                     for o in outs_p))
    ratio = bytes_d / bytes_p
    emit("page_dense_tokens_per_s", tps_d * 1e6,
         f"{n_slots} slots, pool {bytes_d} B")
    emit("page_paged_tokens_per_s", tps_p * 1e6,
         f"{n_pages} pages x {ps}, pool {bytes_p} B")
    emit("page_pool_bytes_ratio", ratio * 1e6, f"parity_match={match}")

    # fixed-memory scaling: double the slots, keep the paged pool inside
    # the dense pool's byte budget, and serve the identical trace
    big = ContinuousEngine(api, params, qcfg, n_slots=2 * n_slots,
                          max_seq=max_seq, cushion=cushion, paged=True,
                          page_size=ps, n_pages=2 * n_pages)
    outs_b, _ = run_engine(big)
    bytes_b = big.stats.pool_bytes
    match_b = (len(outs_b) == n_requests
               and all(np.array_equal(o.tokens, want[o.uid])
                       for o in outs_b))
    emit("page_2x_slots_pool_bytes", bytes_b,
         f"{2 * n_slots} paged slots vs {bytes_d} B dense "
         f"{n_slots}-slot pool, parity={match_b}")

    # prefix caching: 6 requests sharing a 62-token prompt stem (two full
    # 32-position pages under the 3-token cushion), divergent tails
    stem_reqs = poisson_trace(api, 1, 6, rate, (64,), (24,))
    t0 = np.asarray(stem_reqs[0].batch["tokens"])
    for r in stem_reqs[1:]:
        t = np.array(r.batch["tokens"])
        t[:, :62] = t0[:, :62]
        r.batch["tokens"] = jnp.asarray(t)
    dense.run(stem_reqs)                    # warm the new shapes
    outs_sd = dense.run(stem_reqs)
    pfx = ContinuousEngine(api, params, qcfg, n_slots=n_slots,
                           max_seq=max_seq, cushion=cushion, paged=True,
                           page_size=ps, n_pages=n_pages,
                           prefix_cache=True)
    pfx.run(stem_reqs)
    outs_sp = pfx.run(stem_reqs)
    hits, misses = pfx.stats.prefix_hits, pfx.stats.prefix_misses
    want_s = {o.uid: o.tokens for o in outs_sd}
    match_s = (len(outs_sd) == len(stem_reqs) == len(outs_sp)
               and all(np.array_equal(o.tokens, want_s[o.uid])
                       for o in outs_sp))
    emit("page_prefix_hits", hits * 1e6,
         f"misses={misses} parity={match_s}")

    tp_parity = None
    if tp > 1:
        from repro.launch.mesh import make_tp_mesh
        tpe = ContinuousEngine(api, params, qcfg, n_slots=n_slots,
                               max_seq=max_seq, cushion=cushion, paged=True,
                               page_size=ps, n_pages=n_pages,
                               mesh=make_tp_mesh(tp))
        outs_t, _ = run_engine(tpe)
        tp_parity = (len(outs_t) == n_requests
                     and all(np.array_equal(o.tokens, want[o.uid])
                             for o in outs_t))
        emit("page_tp_parity", float(tp_parity) * 1e6,
             f"tp={tp} paged tokens == dense tp=1 tokens")

    point = {"model": cfg.name, "tp": tp, "n_slots": n_slots,
             "n_requests": n_requests, "rate_req_s": rate,
             "prompt_lens": list(prompt_lens), "budgets": list(budgets),
             "max_seq": max_seq, "page_size": ps, "n_pages": n_pages,
             "parity_match": match,
             "pool_bytes_dense": bytes_d, "pool_bytes_paged": bytes_p,
             "pool_bytes_ratio": ratio,
             "tokens_per_s_dense": tps_d, "tokens_per_s_paged": tps_p,
             "tps_ratio": tps_p / tps_d,
             "slots_2x_fixed_memory": {
                 "n_slots": 2 * n_slots, "n_pages": 2 * n_pages,
                 "pool_bytes": bytes_b, "fits_dense_budget":
                     bool(bytes_b <= bytes_d), "parity_match": match_b},
             "prefix_cache": {"n_requests": len(stem_reqs),
                              "stem_tokens": 62, "hits": hits,
                              "misses": misses, "parity_match": match_s},
             "tp_parity": tp_parity,
             **{k: v for k, v in paged.stats.as_dict().items()
                if k.startswith(("pages_", "prefix_", "cushion_",
                                 "positions_"))}}
    out_dir = os.path.join(os.path.dirname(__file__), "..", "results")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "BENCH_pages.json"), "w") as f:
        json.dump({"bench": "pages", "points": [point]}, f, indent=1)
    if not match:
        raise SystemExit("paged pool diverged from the dense pool "
                         "(token parity oracle failed)")
    if ratio < 2.0:
        raise SystemExit(f"paged pool bytes not reduced >= 2x at matched "
                         f"slots: {bytes_d} -> {bytes_p} ({ratio:.2f}x)")
    if not (bytes_b <= bytes_d and match_b):
        raise SystemExit(
            f"2x-slot paged pool failed the fixed-memory gate: "
            f"{bytes_b} B vs dense {bytes_d} B, parity={match_b}")
    if not (match_s and hits >= 1):
        raise SystemExit(f"prefix cache gate failed: hits={hits} "
                         f"parity={match_s}")
    if tps_p < 0.8 * tps_d:
        raise SystemExit(f"paged tokens/s outside noise vs dense: "
                         f"{tps_p:.1f} vs {tps_d:.1f}")
    if tp > 1 and not tp_parity:
        raise SystemExit(f"tp={tp} paged serving diverged from the "
                         f"unsharded dense run")


def cushion_bench(tp: int = 1):
    """CushionCache stage-2 quality gate (``results/BENCH_cushion.json``):
    the full discover -> tune -> serve pipeline on paper_tiny with planted
    activation outliers, measured at three points — no cushion, greedy
    search only, gradient-tuned — and gated so the tuned artifact is never
    worse than what stage 1 already delivered:

    * last-block max-activation top-1 and held-out perplexity per variant;
      tuned must stay within 1.05x of greedy on both (from the greedy
      start, tuning optimizes CE + λ·range — it must not walk quality or
      the outlier suppression backwards)
    * W8A8 accuracy margin (pt_static true-int8 next-token accuracy minus
      fp accuracy), scales calibrated per cushion via ``calibrate_tagged``;
      tuned margin must hold within 0.05 of greedy's
    * the tuning loop's host syncs are counted and bounded at
      steps/log_every + 1 (the per-step-sync regression this pipeline
      fixed)
    * the tuned cushion round-trips through a versioned
      ``checkpoint.store`` artifact fingerprint-identically
    * the restored artifact serves token-for-token identically through the
      static Engine and the continuous scheduler, dense and paged (shared
      cushion block); ``tp > 1`` adds a tensor-parallel continuous run
      (replicated-per-shard cushion) against the same oracle."""
    import json
    import os
    import tempfile

    import jax
    import jax.numpy as jnp
    import numpy as np
    from benchmarks.common import emit
    from repro import monitoring as MON
    from repro.checkpoint.store import CheckpointManager
    from repro.configs import CushionConfig, QuantConfig, get_config
    from repro.core import cushioncache as CC
    from repro.core import outliers as OUT
    from repro.core.calibration import calibrate_tagged
    from repro.launch.serve import poisson_trace
    from repro.models.registry import build
    from repro.serving.engine import Engine
    from repro.serving.scheduler import ContinuousEngine
    from repro.train.trainer import eval_next_token_acc, eval_ppl

    cfg = get_config("paper_tiny")
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    # plant the massive-activation pathway the paper mitigates (same
    # construction as tests/test_cushion.py)
    w = params["layers"]["mlp"]["w_down"]
    params["layers"]["mlp"]["w_down"] = w.at[0, :8, 5].set(300.0)

    qd = QuantConfig(mode="pt_dynamic")
    qn = QuantConfig(mode="none")
    qs = QuantConfig(mode="pt_static", true_int8=True)
    sample = lambda i: api.make_batch(jax.random.PRNGKey(100 + i), 1, 48)
    tune_b = lambda i: api.make_batch(jax.random.PRNGKey(3000 + i), 2, 48)
    eval_batches = [api.make_batch(jax.random.PRNGKey(7000 + i), 2, 48)
                    for i in range(4)]
    calib = [tune_b(100 + i) for i in range(2)]

    steps, log_every = 40, 10
    ccfg = CushionConfig(max_prefix_len=4, tau=1.0, n_candidates=16,
                         seed_tokens=(1,), lam=0.1, tune_steps=steps,
                         tune_lr=1e-3, log_every=log_every)
    greedy, sr, _ = CC.discover(api, params, sample, iter(()), qd, ccfg,
                                jax.random.PRNGKey(1), skip_tune=True,
                                verbose=False)

    def batches():
        i = 0
        while True:
            yield tune_b(i)
            i += 1

    with MON.count_host_syncs() as sync:
        tr = CC.prefix_tune(api, params, greedy, batches(), qd, ccfg,
                            verbose=False)
    tuned = tr.cushion

    from repro.models import transformer as TMOD
    variants = {"none": None, "greedy": greedy, "tuned": tuned}
    metrics = {}
    for name, c in variants.items():
        top1 = OUT.last_block_input_stats(api, params, eval_batches[0],
                                          qn, cushion=c)["top1"]
        ppl = eval_ppl(api, params, eval_batches, qn, cushion=c)
        # total per-site quantization error — the quantity the cushion
        # exists to reduce (paper Table 1's mechanism at CPU scale)
        _, taps = api.forward(params, eval_batches[0], qd, cushion=c,
                              collect=True)
        qerr = float(TMOD.total_qerr(taps))
        tagged, _ = calibrate_tagged(api, params, calib, qs, cushion=c)
        acc_fp = eval_next_token_acc(api, params, eval_batches, qn,
                                     cushion=c)
        acc_w8 = eval_next_token_acc(api, params, eval_batches, qs,
                                     cushion=c, scales=tagged.scales)
        metrics[name] = {"maxact_top1": top1, "ppl": ppl, "qerr": qerr,
                         "acc_fp": acc_fp, "acc_w8": acc_w8,
                         "w8a8_margin": acc_w8 - acc_fp}
        emit(f"cushion_{name}_qerr", qerr * 1e3,
             f"maxact={top1:.1f} ppl={ppl:.2f} "
             f"w8a8_margin={acc_w8 - acc_fp:+.4f}")

    # artifact round trip: the fingerprint survives save/restore
    fp = CC.cushion_fingerprint(tuned)
    with tempfile.TemporaryDirectory() as td:
        store = CheckpointManager(td)
        store.save(1, {"cushion": tuned},
                   extra={"kind": "cushion", "fingerprint": fp})
        tree, _ = store.restore_tree(1)
        restored = jax.tree_util.tree_map(jnp.asarray, tree["cushion"])
    roundtrip_ok = CC.cushion_fingerprint(restored) == fp

    # serving parity on the restored artifact: Engine is the oracle
    reqs = poisson_trace(api, 0, 6, 60.0, (20, 26), (5, 3))
    eng = Engine(api, params, qn, cushion=restored, max_seq=128)
    want = {r.uid: eng.generate(r.batch, r.max_new_tokens).tokens[0]
            for r in reqs}

    def parity(**kw):
        ce = ContinuousEngine(api, params, qn, n_slots=2, max_seq=128,
                              cushion=restored, **kw)
        outs = ce.run(reqs)
        return (len(outs) == len(reqs)
                and all(np.array_equal(o.tokens, want[o.uid])
                        for o in outs))

    par = {"dense": parity(), "paged": parity(paged=True, page_size=32)}
    if tp > 1:
        from repro.launch.mesh import make_tp_mesh
        par[f"tp{tp}"] = parity(mesh=make_tp_mesh(tp))
    emit("cushion_serving_parity",
         float(all(par.values())) * 1e6, str(par))

    sync_bound = steps // log_every + 1
    point = {"model": cfg.name, "tp": tp,
             "prefix_ids": [int(t) for t in sr.prefix_ids],
             "tune_steps": steps, "tune_lr": ccfg.tune_lr,
             "lam": ccfg.lam, "log_every": log_every,
             "tune_host_syncs": sync.count,
             "tune_host_sync_bound": sync_bound,
             "tune_wall_s": tr.wall_time_s,
             "fingerprint": fp, "artifact_roundtrip": roundtrip_ok,
             "metrics": metrics, "serving_parity": par}
    out_dir = os.path.join(os.path.dirname(__file__), "..", "results")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "BENCH_cushion.json"), "w") as f:
        json.dump({"bench": "cushion", "points": [point]}, f, indent=1,
                  default=float)

    g, t = metrics["greedy"], metrics["tuned"]
    if sync.count > sync_bound:
        raise SystemExit(f"tuning host-synced {sync.count}x, bound is "
                         f"{sync_bound} (per-step sync regression)")
    if t["maxact_top1"] > 1.05 * g["maxact_top1"]:
        raise SystemExit(f"tuned max-activation regressed vs greedy: "
                         f"{t['maxact_top1']:.1f} vs {g['maxact_top1']:.1f}")
    if t["ppl"] > 1.05 * g["ppl"]:
        raise SystemExit(f"tuned perplexity regressed vs greedy: "
                         f"{t['ppl']:.2f} vs {g['ppl']:.2f}")
    if t["qerr"] >= metrics["none"]["qerr"]:
        raise SystemExit(f"tuned cushion does not reduce quantization "
                         f"error vs no cushion: {t['qerr']:.2f} vs "
                         f"{metrics['none']['qerr']:.2f}")
    if t["qerr"] > 1.05 * g["qerr"]:
        raise SystemExit(f"tuned qerr regressed vs greedy: "
                         f"{t['qerr']:.2f} vs {g['qerr']:.2f}")
    if t["w8a8_margin"] < g["w8a8_margin"] - 0.05:
        raise SystemExit(f"tuned W8A8 accuracy margin collapsed: "
                         f"{t['w8a8_margin']:+.4f} vs greedy "
                         f"{g['w8a8_margin']:+.4f}")
    if not roundtrip_ok:
        raise SystemExit("tuned cushion artifact did not round-trip "
                         "fingerprint-identically")
    if not all(par.values()):
        raise SystemExit(f"tuned-cushion serving parity failed: {par}")


EXTRA_BENCHES = {"kernel_microbench": kernel_microbench,
                 "decode_bench": decode_bench,
                 "search_bench": search_bench,
                 "serve_bench": serve_bench,
                 "w8a8_bench": w8a8_bench,
                 "w4a8_bench": w4a8_bench,
                 "router_bench": router_bench,
                 "page_bench": page_bench,
                 "cushion_bench": cushion_bench}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run a single bench/table function by name")
    ap.add_argument("--skip-paper", action="store_true",
                    help="kernel microbenches only (fast)")
    ap.add_argument("--tp", type=int, default=1,
                    help="serve_bench/page_bench: tensor-parallel width "
                         "(forces that many XLA host devices on CPU; "
                         "serve_bench emits results/BENCH_tp.json instead "
                         "of BENCH_serve.json; page_bench adds the tp "
                         "paged-parity gate to BENCH_pages.json)")
    ap.add_argument("--replicas", type=int, default=2,
                    help="router_bench only: replica count behind the "
                         "fault-tolerant router")
    args = ap.parse_args()

    # must land before the lazy `import jax` inside the bench fns
    from repro.flags import force_host_device_count
    force_host_device_count(args.tp)

    print("name,us_per_call,derived")
    if args.only in EXTRA_BENCHES:
        kw = {}
        if args.only in ("serve_bench", "page_bench", "cushion_bench",
                         "w4a8_bench"):
            kw = {"tp": args.tp}
        elif args.only == "router_bench":
            kw = {"replicas": args.replicas}
        EXTRA_BENCHES[args.only](**kw)
        return
    kernel_microbench()
    if args.skip_paper:
        return
    if not args.only:
        decode_bench()
        search_bench()
        w8a8_bench()
    from benchmarks import paper_tables as PT
    fns = PT.ALL
    if args.only:
        fns = [f for f in PT.ALL if f.__name__ == args.only]
    for fn in fns:
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            from benchmarks.common import emit
            emit(fn.__name__, 0.0, f"ERROR {type(e).__name__}: {e}")


if __name__ == "__main__":
    main()
