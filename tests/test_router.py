"""Chaos suite for the fault-tolerant replica router (serving/router.py).

The core claim under test: because the cushion/sink prefix KV is replicated
bit-identically on every replica (KVSink/IntactKV) and greedy decode is
batch-composition independent, a request retried from scratch on a
surviving replica reproduces the exact tokens of a no-fault run — so
failover is checkable token-for-token, not just "it didn't crash":

* kill one of K=3 replicas mid-trace -> every request completes, greedy
  tokens identical to the no-fault run, retries/failovers/deaths visible
  in RouterStats;
* all replicas dead -> clean ``AllReplicasDead``, never a hang;
* bounded admission queue -> explicit ``queue_full`` rejections with exact
  counts;
* deadlines -> ``deadline-queued`` (expired waiting) vs
  ``deadline-decoding`` (canceled mid-decode);
* drain under load (injected KeyboardInterrupt) -> live slots complete
  with parity, queued remainder rejected as ``draining``;
* heartbeat corruption -> DEAD via heartbeat-age timeout, work fails over;
* stall -> straggler flagged, replica survives;
* plus deterministic-injector and health-state-machine unit tests.

Every fault schedule is a deterministic ``FailPoint`` (per-site visit
counters), so these tests replay identically run-to-run.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import QuantConfig, get_config
from repro.distributed.fault_injection import (FailPoint, FaultInjector,
                                               InjectedFault)
from repro.distributed.fault_tolerance import (DEAD, DEGRADED, HEALTHY,
                                               HealthTracker)
from repro.models.registry import build
from repro.serving.router import (AllReplicasDead, ReplicaRouter,
                                  RouterConfig)
from repro.serving.scheduler import Request

QN = QuantConfig(mode="none")


# ---------------------------------------------------------------------------
# Unit: deterministic fault injector
# ---------------------------------------------------------------------------

def test_failpoint_schedules_are_deterministic():
    inj = FaultInjector([FailPoint(site="a.step", kind="crash", at_step=2)])
    assert inj.fire("a.step") == []         # visit 0
    assert inj.fire("b.step") == []         # other sites don't advance a's
    assert inj.fire("a.step") == []         # visit 1
    with pytest.raises(InjectedFault) as e:
        inj.fire("a.step")                  # visit 2 -> fires
    assert e.value.site == "a.step" and e.value.step == 2
    assert inj.fire("a.step") == []         # count=1: fired out
    assert inj.log == [("a.step", 2, "crash")]

    inj.reset()                             # rearm: identical replay
    inj.fire("a.step"), inj.fire("a.step")
    with pytest.raises(InjectedFault):
        inj.fire("a.step")


def test_failpoint_seeded_random_step_reproducible():
    a = FaultInjector([FailPoint(site="s", at_step=None, max_step=32)],
                      seed=7)
    b = FaultInjector([FailPoint(site="s", at_step=None, max_step=32)],
                      seed=7)
    assert a.points[0].at_step == b.points[0].at_step
    assert 0 <= a.points[0].at_step < 32


def test_injector_stall_and_heartbeat_actions():
    slept = []
    inj = FaultInjector([
        FailPoint(site="r.step", kind="stall", at_step=1, stall_s=0.25),
        FailPoint(site="r.step", kind="heartbeat", at_step=2)])
    assert inj.fire("r.step", sleep=slept.append) == []
    assert inj.fire("r.step", sleep=slept.append) == ["stall"]
    assert slept == [0.25]
    assert inj.fire("r.step", sleep=slept.append) == ["heartbeat"]


def test_chaos_spec_parsing():
    inj = FaultInjector.parse(
        "crash@replica1.step:12, stall@replica0.step:5:0.25,"
        "heartbeat@replica2.heartbeat:8")
    kinds = [(p.kind, p.site, p.at_step) for p in inj.points]
    assert kinds == [("crash", "replica1.step", 12),
                     ("stall", "replica0.step", 5),
                     ("heartbeat", "replica2.heartbeat", 8)]
    assert inj.points[1].stall_s == 0.25
    with pytest.raises(ValueError, match="bad --chaos entry"):
        FaultInjector.parse("crash-replica1")
    with pytest.raises(ValueError, match="kind"):
        FaultInjector.parse("explode@replica0.step:1")


# ---------------------------------------------------------------------------
# Unit: health-state machine
# ---------------------------------------------------------------------------

def test_health_tracker_state_transitions():
    h = HealthTracker(heartbeat_timeout_s=10.0, dead_after_errors=3,
                      min_history=2)
    h.beat(0.0)
    assert h.state(0.0) == HEALTHY
    h.record_error(1.0)
    assert h.state(1.0) == DEGRADED         # error since last success
    h.record_step(0.01, 2.0)
    assert h.state(2.0) == HEALTHY          # success clears the error
    h.record_error(3.0), h.record_error(4.0), h.record_error(5.0)
    assert h.state(5.0) == DEAD             # 3 consecutive errors
    assert h.errors == 4                    # lifetime count keeps history


def test_health_tracker_heartbeat_age():
    h = HealthTracker(heartbeat_timeout_s=10.0)
    h.beat(0.0)
    assert h.state(4.0) == HEALTHY
    assert h.state(6.0) == DEGRADED         # age > timeout/2
    assert h.state(11.0) == DEAD            # age > timeout
    h.beat(12.0)
    assert h.state(12.0) == HEALTHY         # resumed heartbeat recovers


def test_health_tracker_straggler_and_no_beat():
    h = HealthTracker(straggler_factor=3.0, min_history=2)
    for i in range(3):
        h.record_step(0.01, float(i))
    assert h.record_step(0.2, 4.0, label="slow") is True
    assert h.state(4.0) == DEGRADED and h.stragglers == ["slow"]
    h.record_step(0.01, 5.0, beat=False)    # suppressed heartbeat
    assert h.last_beat == 4.0               # timing recorded, no beat
    h.mark_dead("killed")
    assert h.state(5.0) == DEAD             # sticky


# ---------------------------------------------------------------------------
# Router chaos suite (K=3 replicas on paper_tiny)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def router():
    cfg = get_config("paper_tiny")
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    cushion = api.extract_cushion(params, jnp.asarray([1, 2, 3], jnp.int32),
                                  None, QN)
    r = ReplicaRouter(api, params, QN, n_replicas=3,
                      cfg=RouterConfig(max_queue=64, max_retries=2,
                                       backoff_base_s=0.0),
                      cushion=cushion, n_slots=1, max_seq=128)
    r.api = api     # for request construction in tests
    r.run(_trace(api, 3, budget=2))         # warm/compile every replica
    return r


def _trace(api, n, budget=8, deadline=None, arrival=0.0):
    return [Request(uid=i,
                    batch=api.make_batch(jax.random.PRNGKey(100 + i), 1, 20),
                    max_new_tokens=budget, arrival_s=arrival,
                    deadline_s=deadline)
            for i in range(n)]


@pytest.fixture()
def cfg_guard(router):
    """Restore router policy knobs mutated by a test."""
    import dataclasses
    saved = dataclasses.asdict(router.cfg)
    yield router.cfg
    for k, v in saved.items():
        setattr(router.cfg, k, v)


def test_kill_one_of_three_replicas_token_parity(router):
    """The acceptance gate: kill replica 1 mid-trace; every request still
    completes, with greedy tokens bit-identical to the no-fault run, and
    the retries/failovers are visible in RouterStats."""
    reqs = _trace(router.api, 9, budget=8)
    base = router.run(reqs)
    assert len(base.outputs) == 9 and not base.rejected
    want = {o.uid: o.tokens for o in base.outputs}

    kill = FaultInjector([FailPoint(site="replica1.step", at_step=2)])
    res = router.run(reqs, injector=kill)
    assert len(res.outputs) == 9 and not res.rejected
    for o in res.outputs:
        np.testing.assert_array_equal(o.tokens, want[o.uid])
    st = res.stats
    assert st.replica_deaths == 1
    assert st.failovers >= 1 and st.retries >= 1
    assert st.completed == 9
    states = [p["state"] for p in st.per_replica]
    assert states[1] == DEAD and states.count(DEAD) == 1
    assert any(o.attempts > 1 for o in res.outputs), \
        "a failed-over request must record its retry"


def test_all_replicas_dead_raises_not_hangs(router):
    """Every replica crashing must surface as AllReplicasDead promptly —
    the router may not spin waiting for capacity that will never return."""
    inj = FaultInjector([FailPoint(site=f"replica{i}.step", at_step=0)
                         for i in range(3)])
    t0 = time.perf_counter()
    with pytest.raises(AllReplicasDead, match="3 replicas DEAD"):
        router.run(_trace(router.api, 6, budget=8), injector=inj)
    assert time.perf_counter() - t0 < 30.0
    assert router.stats.replica_deaths == 3


def test_backpressure_queue_full_rejections(router, cfg_guard):
    """Bounded admission queue: arrivals beyond capacity + queue bound get
    explicit queue_full rejections, with exact accounting."""
    cfg_guard.max_queue = 2
    res = router.run(_trace(router.api, 8, budget=4))
    # 8 simultaneous arrivals, queue bound 2: uids 0-1 accepted, 2-7
    # rejected before any dispatch frees capacity
    assert res.stats.rejections == {"queue_full": 6}
    assert res.stats.rejected == 6
    assert {r.reason for r in res.rejected} == {"queue_full"}
    assert sorted(o.uid for o in res.outputs) == [0, 1]
    assert res.stats.submitted == 2 and res.stats.completed == 2
    assert res.stats.queue_depth_peak <= 2


def test_deadline_expires_mid_decode(router):
    """A deadline that passes while the request is decoding cancels the
    slot (deadline-decoding), freeing it without a result."""
    reqs = _trace(router.api, 1, budget=60, deadline=0.035)
    res = router.run(reqs)
    assert not res.outputs
    assert [r.reason for r in res.rejected] == ["deadline-decoding"]
    assert res.stats.rejections == {"deadline-decoding": 1}


def test_deadline_expires_mid_queue(router):
    """A deadline that passes while the request waits in the admission
    queue rejects it as deadline-queued (it never cost a prefill)."""
    long = _trace(router.api, 3, budget=60)             # fill all 3 slots
    victim = Request(uid=3,
                     batch=router.api.make_batch(jax.random.PRNGKey(103),
                                                 1, 20),
                     max_new_tokens=4, deadline_s=0.035)
    res = router.run(long + [victim])
    assert sorted(o.uid for o in res.outputs) == [0, 1, 2]
    assert [(r.uid, r.reason) for r in res.rejected] == \
        [(3, "deadline-queued")]


# ---------------------------------------------------------------------------
# Chunked admission through the router (PREFILLING streams)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def chunked_router():
    cfg = get_config("paper_tiny")
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    cushion = api.extract_cushion(params, jnp.asarray([1, 2, 3], jnp.int32),
                                  None, QN)
    r = ReplicaRouter(api, params, QN, n_replicas=1,
                      cfg=RouterConfig(max_queue=64, backoff_base_s=0.0),
                      cushion=cushion, n_slots=2, max_seq=128,
                      chunk_tokens=8)
    r.api = api
    return r


def test_chunked_streams_complete_through_router(chunked_router):
    """The router keeps stepping an engine whose only work is a PREFILLING
    stream (live_count == 0): long prompts chunk-stream to completion and
    every request is served."""
    api = chunked_router.api
    reqs = [Request(uid=i, batch=api.make_batch(jax.random.PRNGKey(100 + i),
                                                1, [48, 12][i % 2]),
                    max_new_tokens=3)
            for i in range(4)]
    res = chunked_router.run(reqs)
    assert sorted(o.uid for o in res.outputs) == [0, 1, 2, 3]
    assert not res.rejected
    assert res.stats.per_replica[0]["prefill_chunks"] >= 6


def test_deadline_expires_mid_prefill(chunked_router):
    """A deadline blowing between prefill chunks retires the stream with
    an explicit ``deadline-prefill`` rejection (the engine enforces it;
    the router maps ``pop_expired`` to the reason and clears inflight) —
    never ``deadline-decoding``, which is the mid-decode path."""
    api = chunked_router.api
    req = Request(uid=0, batch=api.make_batch(jax.random.PRNGKey(100), 1, 96),
                  max_new_tokens=4, deadline_s=0.02)
    res = chunked_router.run([req])
    assert not res.outputs
    assert [(r.uid, r.reason) for r in res.rejected] == \
        [(0, "deadline-prefill")]
    assert res.stats.rejections == {"deadline-prefill": 1}
    assert res.stats.per_replica[0]["deadline_prefill"] == 1
    assert res.stats.per_replica[0]["canceled"] == 0, \
        "stream expiry is not a decode cancel"


def test_drain_under_load_completes_live_slots(router):
    """An injected KeyboardInterrupt mid-trace takes the graceful-drain
    path: live slots decode to completion (with parity), the queued
    remainder is rejected as draining, and stats.drained is set."""
    reqs = _trace(router.api, 6, budget=16)
    base = router.run(reqs)
    want = {o.uid: o.tokens for o in base.outputs}

    inj = FaultInjector([FailPoint(site="replica0.step", kind="interrupt",
                                   at_step=2)])
    res = router.run(reqs, injector=inj)
    assert res.stats.drained
    # capacity is 3 slots (one per replica): uids 0-2 were live when the
    # interrupt landed and must finish; 3-5 were queued and are rejected
    assert sorted(o.uid for o in res.outputs) == [0, 1, 2]
    for o in res.outputs:
        np.testing.assert_array_equal(o.tokens, want[o.uid])
    assert {r.reason for r in res.rejected} == {"draining"}
    assert sorted(r.uid for r in res.rejected) == [3, 4, 5]


def test_heartbeat_corruption_kills_via_timeout(router, cfg_guard):
    """A corrupted heartbeat (the engine still answers, the liveness signal
    stops refreshing) must kill the replica through heartbeat-age timeout
    and fail its work over — completed requests keep token parity."""
    reqs = _trace(router.api, 6, budget=24)
    base = router.run(reqs)
    want = {o.uid: o.tokens for o in base.outputs}

    cfg_guard.heartbeat_timeout_s = 0.05
    inj = FaultInjector([FailPoint(site="replica1.step", kind="heartbeat",
                                   at_step=1)])
    res = router.run(reqs, injector=inj)
    assert res.stats.replica_deaths >= 1
    assert [p["state"] for p in res.stats.per_replica][1] == DEAD
    assert len(res.outputs) == 6 and not res.rejected
    for o in res.outputs:
        np.testing.assert_array_equal(o.tokens, want[o.uid])


def test_stall_flags_straggler_without_killing(router, cfg_guard):
    """A stalled step trips the straggler detector (DEGRADED territory) but
    must not kill the replica or lose work."""
    cfg_guard.straggler_history = 2
    inj = FaultInjector([FailPoint(site="replica0.step", kind="stall",
                                   at_step=4, stall_s=0.3)])
    res = router.run(_trace(router.api, 3, budget=12), injector=inj)
    assert len(res.outputs) == 3 and not res.rejected
    assert res.stats.replica_deaths == 0
    assert len(router.replicas[0].health.stragglers) >= 1
    assert res.stats.per_replica[0]["stragglers"] >= 1


def test_retries_exhausted_rejects(router, cfg_guard):
    """A replica set that keeps crashing on admission burns the per-request
    retry budget and ends in explicit retries_exhausted rejections (when
    capacity survives elsewhere) or AllReplicasDead (when it doesn't).
    Here replica deaths leave survivors, so the work retries and lands."""
    cfg_guard.max_retries = 0
    # crash replica 0 the moment the first admission touches it: the
    # request's only attempt is burned -> retries_exhausted
    inj = FaultInjector([FailPoint(site="replica0.admit", at_step=0)])
    res = router.run(_trace(router.api, 3, budget=4), injector=inj)
    assert res.stats.replica_deaths == 1
    assert res.stats.rejections.get("retries_exhausted", 0) >= 1
    # the untouched requests still complete on replicas 1 and 2
    assert len(res.outputs) == 2
