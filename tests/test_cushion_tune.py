"""CushionCache stage-2 pipeline tests: the production prefix-tuning loop
(periodic host syncs, dtype-following, family coverage), the fingerprint
contract between tuned artifacts and pt_static scales, and end-to-end
serving parity for a *tuned* cushion across every pool layout."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import monitoring as MON
from repro.configs import CushionConfig, QuantConfig, get_config, reduced
from repro.core import cushioncache as CC
from repro.core.calibration import (CalibratedScales, calibrate_tagged,
                                    scales_from_plain, scales_to_plain)
from repro.models.registry import build
from repro.serving.engine import Engine
from repro.serving.scheduler import ContinuousEngine, Request

QD = QuantConfig(mode="pt_dynamic")
QN = QuantConfig(mode="none")


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("paper_tiny")
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    return api, params


def _batches(api, n=2, s=24, base=3000):
    i = 0
    while True:
        yield api.make_batch(jax.random.PRNGKey(base + i), n, s)
        i += 1


@pytest.fixture(scope="module")
def tuned(tiny):
    """A genuinely gradient-tuned cushion (not just extracted KV) shared by
    the serving-parity cases below."""
    api, params = tiny
    greedy = api.extract_cushion(params, jnp.asarray([1, 2, 3], jnp.int32),
                                 None, QN)
    ccfg = CushionConfig(tune_steps=6, tune_lr=1e-3, lam=0.1, log_every=3)
    tr = CC.prefix_tune(api, params, greedy, _batches(api), QD, ccfg,
                        verbose=False)
    # tuning must actually have moved the KV, or the parity cases degrade
    # into the already-covered extracted-cushion ones
    assert not np.array_equal(np.asarray(tr.cushion["kv"]["k"]),
                              np.asarray(greedy["kv"]["k"]))
    return tr.cushion


def test_tune_host_syncs_bounded(tiny):
    """The regression this PR fixes: the tuning loop must NOT host-sync
    per step. Metrics drain every ccfg.log_every steps, so a 12-step run
    at log_every=4 performs at most 12/4 + 1 blocking transfers — while
    still logging one record per step."""
    api, params = tiny
    cush0 = api.extract_cushion(params, jnp.asarray([1, 2], jnp.int32),
                                None, QN)
    ccfg = CushionConfig(tune_steps=12, tune_lr=1e-3, lam=0.1, log_every=4)
    with MON.count_host_syncs() as c:
        tr = CC.prefix_tune(api, params, cush0, _batches(api), QD, ccfg,
                            verbose=False)
    assert c.count <= 12 // 4 + 1, c.count
    assert len(tr.log) == 12
    assert all(np.isfinite(r["loss"]) for r in tr.log)
    # per-step metrics survive the batched drain in order
    assert [r["step"] for r in tr.log] == list(range(12))


@pytest.mark.parametrize("arch", ["olmoe-1b-7b", "internvl2-26b",
                                  "jamba-v0.1-52b"])
def test_prefix_tune_families(arch):
    """prefix_tune runs on MoE / VLM / hybrid: finite losses, the cushion
    KV moves, and (hybrid) the recurrent-state leaves stay bit-identical —
    they are frozen passthroughs, not trainables."""
    cfg = reduced(get_config(arch), dtype="float32")
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    cush0 = api.extract_cushion(params, jnp.asarray([1, 2], jnp.int32),
                                None, QN)
    ccfg = CushionConfig(tune_steps=3, tune_lr=1e-3, lam=0.05, log_every=2)
    tr = CC.prefix_tune(api, params, cush0, _batches(api, s=16), QD, ccfg,
                        verbose=False)
    assert all(np.isfinite(r["loss"]) for r in tr.log)
    assert not np.array_equal(np.asarray(tr.cushion["kv"]["k"]),
                              np.asarray(cush0["kv"]["k"])), arch
    if "state" in cush0:
        for a, b in zip(jax.tree_util.tree_leaves(cush0["state"]),
                        jax.tree_util.tree_leaves(tr.cushion["state"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cushion_dtype_follows_model():
    """The artifact keeps the model dtype end to end (the fp32-cast bug):
    a bf16 model's extracted cushion is bf16 and tuning preserves it."""
    cfg = reduced(get_config("paper_tiny"), dtype="bfloat16")
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    cush = api.extract_cushion(params, jnp.asarray([1, 2], jnp.int32),
                               None, QN)
    assert cush["kv"]["k"].dtype == jnp.bfloat16
    ccfg = CushionConfig(tune_steps=2, tune_lr=1e-3, lam=0.05, log_every=2)
    tr = CC.prefix_tune(api, params, cush, _batches(api, s=16), QD, ccfg,
                        verbose=False)
    assert tr.cushion["kv"]["k"].dtype == jnp.bfloat16
    assert tr.cushion["kv"]["v"].dtype == jnp.bfloat16


def test_scales_plain_roundtrip(tiny):
    """scales_to_plain/scales_from_plain is the artifact (de)serialization
    pair: SiteScale leaves survive a round trip bit-identically."""
    api, params = tiny
    calib = [api.make_batch(jax.random.PRNGKey(9000 + i), 2, 24)
             for i in range(2)]
    qs = QuantConfig(mode="pt_static", true_int8=True)
    tagged, _ = calibrate_tagged(api, params, calib, qs, cushion=None)
    back = scales_from_plain(scales_to_plain(tagged.scales))
    for a, b in zip(jax.tree_util.tree_leaves(tagged.scales),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert tagged.cushion_fp == CC.cushion_fingerprint(None)


def test_stale_scales_rejected(tiny, tuned):
    """The stale-scale footgun: pt_static scales calibrated under cushion A
    must refuse to serve under cushion B — and serve fine under A."""
    api, params = tiny
    calib = [api.make_batch(jax.random.PRNGKey(9100 + i), 2, 24)
             for i in range(2)]
    qs = QuantConfig(mode="pt_static", true_int8=True)
    tagged, _ = calibrate_tagged(api, params, calib, qs, cushion=tuned)
    other = api.extract_cushion(params, jnp.asarray([5, 6], jnp.int32),
                                None, QN)
    with pytest.raises(ValueError, match="stale"):
        Engine(api, params, qs, cushion=other, scales=tagged, max_seq=64)
    with pytest.raises(ValueError, match="stale"):
        ContinuousEngine(api, params, qs, n_slots=2, max_seq=64,
                         cushion=None, scales=tagged)
    eng = Engine(api, params, qs, cushion=tuned, scales=tagged, max_seq=64)
    assert eng.cushion_fp == tagged.cushion_fp
    res = eng.generate(api.make_batch(jax.random.PRNGKey(7), 1, 16), 4)
    assert res.tokens.shape == (1, 4)


def test_fingerprint_sensitivity(tiny, tuned):
    """The fingerprint covers content, dtype and shape — any drift in what
    would be served changes it."""
    fp = CC.cushion_fingerprint(tuned)
    assert fp == CC.cushion_fingerprint(jax.tree_util.tree_map(jnp.array,
                                                               tuned))
    bumped = jax.tree_util.tree_map(lambda x: x, tuned)
    bumped["kv"] = dict(tuned["kv"])
    bumped["kv"]["k"] = tuned["kv"]["k"].at[0, 0, 0, 0].add(1e-3)
    assert CC.cushion_fingerprint(bumped) != fp
    cast = {"kv": {k: v.astype(jnp.bfloat16)
                   for k, v in tuned["kv"].items()}}
    assert CC.cushion_fingerprint(cast) != fp
    assert CC.cushion_fingerprint(None) == "none"


@pytest.mark.parametrize("kv_dtype,paged,chunk", [
    (None, False, None),        # dense fp pool
    ("int8", False, None),      # dense int8 pool (fp cushion block)
    (None, True, None),         # paged pool, shared cushion block
    (None, False, 16),          # chunked chunk-0 prefill
])
def test_tuned_cushion_serving_parity(tiny, tuned, kv_dtype, paged, chunk):
    """A *tuned* cushion serves token-for-token identically through the
    static Engine and the continuous scheduler across pool layouts, with
    recycling rewriting the tuned block bit-identically."""
    api, params = tiny
    budgets = [5, 3, 6, 4, 5]
    lens = [20, 26]
    reqs = [Request(uid=i, batch=api.make_batch(jax.random.PRNGKey(100 + i),
                                                1, lens[i % 2]),
                    max_new_tokens=n)
            for i, n in enumerate(budgets)]
    ce = ContinuousEngine(api, params, QN, n_slots=2, max_seq=128,
                          cushion=tuned, kv_dtype=kv_dtype, paged=paged,
                          page_size=32 if paged else 64,
                          chunk_tokens=chunk)
    outs = ce.run(reqs)
    assert ce.stats.finished == len(reqs)
    assert ce.stats.recycles >= 1

    eng = Engine(api, params, QN, cushion=tuned, max_seq=128,
                 kv_dtype=kv_dtype)
    for req, out in zip(reqs, outs):
        ref = eng.generate(req.batch, req.max_new_tokens).tokens[0]
        np.testing.assert_array_equal(out.tokens, ref)

    m = ce.prefix_len
    assert ce.cushion_fp == eng.cushion_fp == CC.cushion_fingerprint(tuned)
    if paged:
        want = np.asarray(tuned["kv"]["k"]).astype(
            ce.cushion_block["kc"].dtype)
        np.testing.assert_array_equal(np.asarray(ce.cushion_block["kc"]),
                                      want)
    elif kv_dtype == "int8":
        want = np.asarray(tuned["kv"]["k"]).astype(ce.cache["kc"].dtype)
        np.testing.assert_array_equal(np.asarray(ce.cache["kc"]), want)
    else:
        want = np.asarray(tuned["kv"]["k"]).astype(ce.cache["k"].dtype)
        for s in range(ce.n_slots):
            np.testing.assert_array_equal(
                np.asarray(ce.cache["k"][:, s, :m]), want)


def test_tune_launcher_artifact_roundtrip(tmp_path):
    """launch/tune.py writes a versioned artifact that
    launch/serve.load_cushion_artifact restores fingerprint-verified, and
    an arch mismatch at load is an explicit failure."""
    from repro.launch import serve as serve_mod
    from repro.launch import tune as tune_mod

    out = str(tmp_path / "art")
    tune_mod.main(["--arch", "paper_tiny", "--steps", "2",
                   "--log-every", "2", "--candidates", "8",
                   "--max-prefix-len", "2", "--sample-len", "24",
                   "--seq-len", "24", "--eval-batches", "1",
                   "--with-scales", "--out-dir", out])
    api = build(get_config("paper_tiny"))
    cushion, scales, extra = serve_mod.load_cushion_artifact(out, api)
    assert extra["kind"] == "cushion"
    assert CC.cushion_fingerprint(cushion) == extra["fingerprint"]
    assert isinstance(scales, CalibratedScales)
    assert scales.cushion_fp == extra["scales_cushion_fp"] \
        == extra["fingerprint"]

    other = build(reduced(get_config("paper_tiny"), dtype="float32"))
    with pytest.raises(SystemExit, match="arch"):
        serve_mod.load_cushion_artifact(out, other)
