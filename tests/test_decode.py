"""Serving decode fast path: split-KV flash-decode kernel parity
(interpret mode vs the jnp oracle; fp and int8-KV, cushion prefix on and
off, non-tile-aligned positions), quantized-cache decode fidelity, and the
device-resident Engine scan loop's equivalence to the per-token host loop.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import QuantConfig, get_config, reduced
from repro.kernels import ref as R
from repro.kernels.flash_decode import flash_decode
from repro.models.registry import build
from repro.serving.engine import Engine

QN = QuantConfig(mode="none")


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("B,K,G,hd,Smax,pos,bkv", [
    (1, 2, 3, 32, 96, 41, 32),      # non-tile-aligned pos, odd G
    (2, 2, 1, 64, 128, 77, 64),     # MHA-style (G=1)
    (2, 1, 4, 16, 80, 13, 32),      # pos inside first chunk
    (1, 4, 2, 32, 64, 63, 64),      # full cache, single chunk
])
def test_flash_decode_fp_parity(B, K, G, hd, Smax, pos, bkv):
    rs = np.random.RandomState(B + K + G + Smax + pos)
    q = jnp.asarray(rs.randn(B, K * G, hd).astype(np.float32))
    k = jnp.asarray(rs.randn(B, Smax, K, hd).astype(np.float32))
    v = jnp.asarray(rs.randn(B, Smax, K, hd).astype(np.float32))
    out = flash_decode(q, k, v, pos, bkv=bkv, interpret=True)
    ref = R.flash_decode_ref(q, k, v, pos)
    assert float(jnp.abs(out - ref).max()) < 1e-2   # acceptance bound
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,pos", [(0, 50), (5, 50), (5, 7), (16, 23)])
def test_flash_decode_int8_parity(m, pos):
    """int8 cache with per-head dequant scales; the cushion block [0:m)
    comes from a separate fp ref (protected sink block)."""
    B, K, G, hd, Smax = 2, 2, 2, 32, 96
    rs = np.random.RandomState(m + pos)
    q = jnp.asarray(rs.randn(B, K * G, hd).astype(np.float32))
    kq = jnp.asarray(rs.randint(-127, 128, (B, Smax, K, hd)), jnp.int8)
    vq = jnp.asarray(rs.randint(-127, 128, (B, Smax, K, hd)), jnp.int8)
    ks = jnp.asarray(rs.rand(K).astype(np.float32) * 0.05 + 0.01)
    vs = jnp.asarray(rs.rand(K).astype(np.float32) * 0.05 + 0.01)
    kc = vc = None
    if m:
        kc = jnp.asarray(rs.randn(m, K, hd).astype(np.float32))
        vc = jnp.asarray(rs.randn(m, K, hd).astype(np.float32))
    out = flash_decode(q, kq, vq, pos, k_scale=ks, v_scale=vs, kc=kc, vc=vc,
                       bkv=32, interpret=True)
    ref = R.flash_decode_ref(q, kq, vq, pos, k_scale=ks, v_scale=vs,
                             kc=kc, vc=vc)
    assert float(jnp.abs(out - ref).max()) < 1e-2   # acceptance bound
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("arch", ["smollm-360m", "jamba-v0.1-52b"])
def test_int8_cache_decode_close_to_fp(arch, rng):
    """prefill + decode over the int8 KV cache (cushion intact in fp) stays
    close to the fp cache path — same argmax tokens on a smoke model."""
    cfg = reduced(get_config(arch), dtype="float32")
    api = build(cfg)
    params = api.init_params(rng)
    batch = api.make_batch(rng, 2, 16)
    cushion = jax.tree_util.tree_map(lambda a: a * 0 + 0.03,
                                     api.cushion_zeros(4))
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :8]
    cache_fp = api.init_cache(2, 64)
    cache_q = api.init_cache(2, 64, kv_dtype="int8", prefix_len=4)
    lf, cache_fp, pf = api.prefill(params, pre, cache_fp, QN, cushion=cushion)
    lq, cache_q, pq = api.prefill(params, pre, cache_q, QN, cushion=cushion)
    # prefill path is identical (quantization only affects the cache store)
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lq), atol=1e-5)
    agree = []
    for i in range(8, 12):
        lf, cache_fp = api.decode_step(params, batch["tokens"][:, i], pf,
                                       cache_fp, QN)
        lq, cache_q = api.decode_step(params, batch["tokens"][:, i], pq,
                                      cache_q, QN)
        pf, pq = pf + 1, pq + 1
        # int8-KV error stays small relative to the logit range
        rel = float(jnp.abs(lf - lq).max() / jnp.abs(lf).max())
        assert rel < 0.15, rel
        agree.append(np.asarray(jnp.argmax(lf, -1) == jnp.argmax(lq, -1)))
    assert np.concatenate(agree).mean() >= 0.75


def test_engine_scan_matches_python_loop(rng):
    """The device-resident lax.scan generation loop reproduces the legacy
    per-token host loop's greedy tokens exactly."""
    cfg = reduced(get_config("smollm-360m"), dtype="float32")
    api = build(cfg)
    params = api.init_params(rng)
    batch = api.make_batch(rng, 2, 12)
    eng = Engine(api, params, QN, max_seq=48)
    scanned = eng.generate(batch, 7)
    looped = eng.generate_py(batch, 7)
    np.testing.assert_array_equal(scanned.tokens, looped.tokens)
    assert scanned.tokens.shape == (2, 7)


def test_engine_scan_matches_python_loop_with_cushion(rng):
    cfg = reduced(get_config("smollm-360m"), dtype="float32")
    api = build(cfg)
    params = api.init_params(rng)
    batch = api.make_batch(rng, 2, 12)
    cushion = api.extract_cushion(params, jnp.asarray([1, 2], jnp.int32),
                                  None, QN)
    eng = Engine(api, params, QN, cushion=cushion, max_seq=48)
    scanned = eng.generate(batch, 6)
    looped = eng.generate_py(batch, 6)
    np.testing.assert_array_equal(scanned.tokens, looped.tokens)


def test_engine_int8_kv_generates(rng):
    """End-to-end int8-KV serving with a cushion prefix: scanned loop runs
    and matches its own python-loop reference token-for-token."""
    cfg = reduced(get_config("smollm-360m"), dtype="float32")
    api = build(cfg)
    params = api.init_params(rng)
    batch = api.make_batch(rng, 2, 12)
    cushion = api.extract_cushion(params, jnp.asarray([1, 2], jnp.int32),
                                  None, QN)
    eng = Engine(api, params, QN, cushion=cushion, max_seq=48,
                 kv_dtype="int8")
    scanned = eng.generate(batch, 6)
    looped = eng.generate_py(batch, 6)
    np.testing.assert_array_equal(scanned.tokens, looped.tokens)
    assert scanned.tokens.shape == (2, 6)


def test_sampling_under_scan(rng):
    """Categorical sampling inside the scan: deterministic for a fixed key
    and shaped correctly."""
    cfg = reduced(get_config("smollm-360m"), dtype="float32")
    api = build(cfg)
    params = api.init_params(rng)
    batch = api.make_batch(rng, 2, 8)
    eng = Engine(api, params, QN, max_seq=32)
    a = eng.generate(batch, 5, greedy=False, rng=jax.random.PRNGKey(3))
    b = eng.generate(batch, 5, greedy=False, rng=jax.random.PRNGKey(3))
    np.testing.assert_array_equal(a.tokens, b.tokens)
    assert a.tokens.shape == (2, 5)


def test_token_budget_bucketing(rng):
    """Distinct token budgets in the same bucket share one compiled scan;
    sliced outputs still match the per-token host loop exactly."""
    from repro.monitoring import count_compiles
    from repro.serving.engine import bucket_steps

    assert [bucket_steps(n) for n in (0, 1, 7, 8, 9, 100)] == \
        [0, 8, 8, 8, 16, 128]

    cfg = reduced(get_config("smollm-360m"), dtype="float32")
    api = build(cfg)
    params = api.init_params(rng)
    batch = api.make_batch(rng, 2, 12)
    eng = Engine(api, params, QN, max_seq=64)
    first = eng.generate(batch, 6)          # compiles the 8-step bucket
    with count_compiles() as c:
        second = eng.generate(batch, 9)     # same bucket -> cache hit
    assert c.count == 0, c.count
    assert first.tokens.shape == (2, 6)
    assert second.tokens.shape == (2, 9)
    looped = eng.generate_py(batch, 9)
    np.testing.assert_array_equal(second.tokens, looped.tokens)


def test_bucket_surplus_steps_near_max_seq(rng):
    """Regression for the bucket_steps surplus-step claim: a request whose
    bucket-padded scan runs past ``max_seq`` (surplus cache writes clamp
    into the last row) still delivers uncorrupted tokens — the clamped
    writes only ever touch positions read by the discarded surplus steps."""
    from repro.serving.engine import bucket_steps

    cfg = reduced(get_config("smollm-360m"), dtype="float32")
    api = build(cfg)
    params = api.init_params(rng)
    batch = api.make_batch(rng, 2, 115)
    n_tokens = 10                               # 9 steps -> 16-step bucket
    eng = Engine(api, params, QN, max_seq=128)  # prompt 115 + 16 > 128
    assert eng.max_seq == 128
    assert 115 + bucket_steps(n_tokens - 1) > eng.max_seq   # surplus clamps
    scanned = eng.generate(batch, n_tokens)
    looped = eng.generate_py(batch, n_tokens)   # exact-step reference
    np.testing.assert_array_equal(scanned.tokens, looped.tokens)
    assert scanned.tokens.shape == (2, n_tokens)


def test_tpot_zero_for_single_token(rng):
    """TPOT is latency per *subsequent* token: n_tokens <= 1 has none, so
    both generation paths report 0.0 instead of dividing loop overhead by
    a clamped denominator."""
    cfg = reduced(get_config("smollm-360m"), dtype="float32")
    api = build(cfg)
    params = api.init_params(rng)
    batch = api.make_batch(rng, 2, 8)
    eng = Engine(api, params, QN, max_seq=32)
    for res in (eng.generate(batch, 1), eng.generate_py(batch, 1)):
        assert res.tpot_ms == 0.0
        assert res.tokens.shape == (2, 1)
        assert res.ttft_ms > 0.0
