"""Substrate tests: data pipeline, checkpoint store, fault-tolerance
supervisor, compressed collectives, smoothquant, partition rules."""
import os

import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:          # optional dev dep: only one test needs it
    hypothesis = st = None

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointManager
from repro.configs import QuantConfig, get_config
from repro.data.pipeline import Pipeline, SyntheticCorpus, calibration_batches
from repro.distributed.collectives import (compressed_psum,
                                           dp_train_step_compressed)
from repro.distributed.fault_tolerance import Supervisor


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_resumable():
    c = SyntheticCorpus(128, seed=3)
    p = Pipeline(c, batch=4, seq_len=32, seed=7)
    b1 = p.get_batch(5)
    b2 = p.get_batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    it = p.iter_from(5)
    b3 = next(it)
    np.testing.assert_array_equal(b1["tokens"], b3["tokens"])


def test_pipeline_host_disjoint():
    c = SyntheticCorpus(128, seed=3)
    a = Pipeline(c, batch=4, seq_len=32, seed=7, host=0, n_hosts=2)
    b = Pipeline(c, batch=4, seq_len=32, seed=7, host=1, n_hosts=2)
    assert not np.array_equal(a.get_batch(0)["tokens"],
                              b.get_batch(0)["tokens"])


def test_labels_are_shifted_tokens():
    c = SyntheticCorpus(128, seed=0)
    p = Pipeline(c, batch=2, seq_len=16, seed=0)
    b = p.get_batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_corpus_is_learnable():
    """Bigram structure: successor entropy must be far below uniform."""
    c = SyntheticCorpus(64, seed=0)
    assert c.successors.shape[1] < 64


# ---------------------------------------------------------------------------
# checkpoints
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    cm.save(10, tree, extra={"note": "x"})
    out = cm.restore(10, like=tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert out["b"]["c"].dtype == np.asarray(tree["b"]["c"]).dtype


def test_checkpoint_keep_k_gc(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.zeros((2,))}
    for s in [1, 2, 3, 4]:
        cm.save(s, tree)
    assert cm.steps() == [3, 4]


def test_checkpoint_integrity_detection(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=3)
    tree = {"a": jnp.zeros((128,))}
    path = cm.save(5, tree)
    with open(os.path.join(path, "arrays.npz"), "r+b") as f:
        f.seek(100)
        f.write(b"\x00corrupt\x00")
    with pytest.raises(IOError):
        cm.restore(5, like=tree)


def test_checkpoint_reshard_on_restore(tmp_path):
    """Elastic restore: device_put with new shardings (1-device here, but
    exercises the reshard path)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    cm = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    cm.save(1, tree)
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("data",))
    sh = {"w": NamedSharding(mesh, P())}
    out = cm.restore(1, like=tree, shardings=sh)
    assert out["w"].sharding == sh["w"]


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_supervisor_restores_after_failure(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=3)
    state0 = {"x": jnp.zeros(())}
    calls = {"n": 0}

    failed = {"done": False}

    def do_step(state, step):
        calls["n"] += 1
        if step == 7 and not failed["done"]:   # fail once at step 7
            failed["done"] = True
            raise RuntimeError("injected node failure")
        return {"x": state["x"] + 1}, {"loss": float(state["x"])}

    sup = Supervisor(cm, save_every=5, max_retries=3)
    state, report = sup.run(state0, 0, 10, do_step)
    assert report.failures == 1
    assert report.restores == 1
    # deterministic replay: x counts exactly the 10 logical steps
    assert float(state["x"]) == 10.0


def test_supervisor_gives_up_without_checkpoint(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    sup = Supervisor(cm, save_every=100)

    def bad(state, step):
        raise RuntimeError("dead on arrival")

    with pytest.raises(RuntimeError):
        sup.run({"x": jnp.zeros(())}, 0, 5, bad)


# ---------------------------------------------------------------------------
# compressed collectives
# ---------------------------------------------------------------------------

def _check_compressed_psum(seed):
    from jax.sharding import Mesh
    from repro.distributed.collectives import shard_map_compat
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(1, 64).astype(np.float32))
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    out = shard_map_compat(lambda v: compressed_psum(v, "data"), mesh,
                           in_specs=jax.sharding.PartitionSpec("data"),
                           out_specs=jax.sharding.PartitionSpec("data"))(x)
    scale = np.abs(np.asarray(x)).max() / 127.0
    assert np.abs(np.asarray(out) - np.asarray(x)).max() <= scale * 0.51 + 1e-7


if hypothesis is not None:
    @hypothesis.settings(max_examples=10, deadline=None)
    @hypothesis.given(st.integers(0, 2 ** 31 - 1))
    def test_compressed_psum_close_to_exact(seed):
        _check_compressed_psum(seed)
else:
    def test_compressed_psum_close_to_exact():
        _check_compressed_psum(0)       # single deterministic example


def test_dp_train_step_compressed_runs():
    from jax.sharding import Mesh
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))

    def grad_fn(params, batch):
        loss = jnp.mean((batch @ params) ** 2)
        return loss, jax.grad(lambda p: jnp.mean((batch @ p) ** 2))(params)

    fn = dp_train_step_compressed(grad_fn, mesh)
    params = jnp.ones((8, 4))
    batch = jnp.ones((2, 8))
    loss, grads = fn(params, batch)
    assert np.isfinite(float(loss))
    assert grads.shape == params.shape


# ---------------------------------------------------------------------------
# smoothquant & partition rules
# ---------------------------------------------------------------------------

def test_smoothquant_flattens_activations():
    from repro.core.calibration import calibrate
    from repro.core.smoothquant import apply_smoothquant
    from repro.models.registry import build
    cfg = get_config("paper_tiny")
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    # plant a hot input channel for the mlp
    g = params["layers"]["ln2"]["g"]
    params["layers"]["ln2"]["g"] = g.at[:, 3].set(50.0)
    batches = [api.make_batch(jax.random.PRNGKey(i), 2, 32) for i in range(2)]
    qs = QuantConfig(mode="pt_static")
    _, stats = calibrate(api, params, batches, qs)
    before = np.asarray(stats["layers"]["mlp_in"]["absmax_ch"])
    sm = apply_smoothquant(params, stats, cfg, alpha=0.8)
    _, stats2 = calibrate(api, sm, batches, qs)
    after = np.asarray(stats2["layers"]["mlp_in"]["absmax_ch"])
    assert after.max() < before.max()


def test_partition_rules_divisibility():
    from jax.sharding import Mesh
    from repro.distributed.sharding import params_shardings
    from repro.models.registry import build
    from repro.configs import reduced
    cfg = reduced(get_config("smollm-360m"), dtype="float32")
    api = build(cfg)
    p_abs = jax.eval_shape(
        lambda: api.init_params(jax.random.PRNGKey(0)))
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    sh = params_shardings(p_abs, mesh)
    # every leaf got a sharding without error
    assert len(jax.tree_util.tree_leaves(sh)) == \
        len(jax.tree_util.tree_leaves(p_abs))


def test_serve_rules_drop_fsdp_axis():
    from repro.distributed.sharding import DEFAULT_RULES, serve_rules
    sr = dict(serve_rules())
    dr = dict(DEFAULT_RULES)
    assert sr[r"attn/wqkv$"] == (None, "M")
    assert dr[r"attn/wqkv$"] == ("D", "M")


def test_placeholder_all_scales_every_family():
    from repro.configs import ARCH_IDS, get_config, reduced
    from repro.models.registry import build
    for arch in ARCH_IDS:
        cfg = reduced(get_config(arch), dtype="float32")
        api = build(cfg)
        sc = api.mod.placeholder_all_scales(cfg)
        assert "head" in sc, arch
