"""Paged KV pool contract (``serving/paging.py`` + ``flash_decode_paged``):

* Kernel bit-exactness: for random permutation page tables the paged
  Pallas kernel is BIT-identical to the contiguous kernel run at
  ``bkv=page_size`` over the gathered cache — fp pools and int8 pools with
  per-slot (B, K) scales and a fp cushion block, including retired rows
  (pos == -1) reading only the scratch page. fp + cushion folds the
  cushion in a different order than the contiguous kernel, so that
  combination is gated against the gather oracle (allclose) instead.
* Allocator invariants: reservation-based admission backpressure, page
  accounting across release/re-admit, scratch page pinned forever.
* Scheduler parity: the paged pool serves a recycling trace token-for-token
  identical to the per-request static Engine, fp and int8 (per-slot scale
  pages), and re-admission into a recycled slot never copies the cushion
  block (the same two device buffers serve the engine's whole session).
* Prefix caching: a repeated prompt stem hits the content-addressed page
  registry and skips its prefill chunk token-for-token.
* tp=2 paged parity (guarded on host device count) and the explicit
  no-slot-layout / non-pageable-family rejections.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import QuantConfig, get_config, reduced
from repro.kernels import ref as R
from repro.kernels.flash_decode import flash_decode, flash_decode_paged
from repro.models.registry import build
from repro.serving import ContinuousEngine, Engine, Request
from repro.serving.paging import PagePool

try:                    # property tests degrade to the deterministic cases
    import hypothesis
    import hypothesis.strategies as st
except ImportError:     # pragma: no cover
    hypothesis = st = None

QN = QuantConfig(mode="none")

# ---------------------------------------------------------------------------
# Kernel: paged == contiguous, bit for bit
# ---------------------------------------------------------------------------

_B, _K, _G, _HD, _SMAX, _PS, _M = 4, 2, 2, 16, 64, 32, 8
_P = _SMAX // _PS
_RS = np.random.RandomState(11)
_Q = jnp.asarray(_RS.randn(_B, _K * _G, _HD).astype(np.float32))
_KF = _RS.randn(_B, _SMAX, _K, _HD).astype(np.float32)
_VF = _RS.randn(_B, _SMAX, _K, _HD).astype(np.float32)
_KQ = _RS.randint(-127, 128, (_B, _SMAX, _K, _HD)).astype(np.int8)
_VQ = _RS.randint(-127, 128, (_B, _SMAX, _K, _HD)).astype(np.int8)
_KSR = jnp.asarray(_RS.rand(_B, _K).astype(np.float32) * 0.05 + 0.01)
_VSR = jnp.asarray(_RS.rand(_B, _K).astype(np.float32) * 0.05 + 0.01)
_KC = jnp.asarray(_RS.randn(_M, _K, _HD).astype(np.float32))
_VC = jnp.asarray(_RS.randn(_M, _K, _HD).astype(np.float32))


def _paginate(k, v, seed, n_extra=3):
    """Scatter dense (B, Smax, K, hd) rows into a random-permutation page
    store: page 0 stays scratch (junk content — it must never influence the
    output), logical page j of row b lands on physical page table[b, j]."""
    rs = np.random.RandomState(seed)
    n_pages = _B * _P + 1 + n_extra
    perm = rs.permutation(np.arange(1, n_pages))[:_B * _P]
    table = perm.reshape(_B, _P).astype(np.int32)
    kp = rs.randn(n_pages, _PS, _K, _HD).astype(np.float32).astype(k.dtype)
    vp = rs.randn(n_pages, _PS, _K, _HD).astype(np.float32).astype(v.dtype)
    kp[table.reshape(-1)] = k.reshape(_B * _P, _PS, _K, _HD)
    vp[table.reshape(-1)] = v.reshape(_B * _P, _PS, _K, _HD)
    return jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(table)


def _check_paged_kernel(pos, quantized, seed=0):
    posv = jnp.asarray(pos, jnp.int32)
    if quantized:
        kp, vp, table = _paginate(_KQ, _VQ, seed)
        out = flash_decode_paged(_Q, kp, vp, table, posv, k_scale=_KSR,
                                 v_scale=_VSR, kc=_KC, vc=_VC,
                                 interpret=True)
        # same chunk size, same online-softmax fold order -> bit-exact
        ref = flash_decode(_Q, jnp.asarray(_KQ), jnp.asarray(_VQ), posv,
                           k_scale=_KSR, v_scale=_VSR, kc=_KC, vc=_VC,
                           bkv=_PS, interpret=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    else:
        kp, vp, table = _paginate(_KF, _VF, seed)
        out = flash_decode_paged(_Q, kp, vp, table, posv, interpret=True)
        ref = flash_decode(_Q, jnp.asarray(_KF), jnp.asarray(_VF), posv,
                           bkv=_PS, interpret=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("quantized", [False, True], ids=["fp", "int8"])
@pytest.mark.parametrize("pos", [
    [_M, -1, _SMAX - 1, _M - 1],    # cushion boundary, retired, full
    [-1, -1, -1, 5],                # mostly-retired pool
    [0, 17, _PS - 1, _PS],          # page-edge straddle
    [3, 60, -1, 33],                # ragged mid-decode pool
])
def test_paged_kernel_bit_identical_cases(pos, quantized):
    """Deterministic cases (always run, even without hypothesis): the paged
    kernel reproduces the contiguous kernel BIT-for-bit over permuted page
    tables — fp, and int8 with per-slot (B, K) scales + fp cushion —
    including fully retired rows whose table points at freed pages."""
    _check_paged_kernel(pos, quantized)


if hypothesis is not None:
    @hypothesis.given(
        pos=st.lists(st.integers(min_value=-1, max_value=_SMAX - 1),
                     min_size=_B, max_size=_B),
        quantized=st.booleans(),
        seed=st.integers(min_value=0, max_value=2 ** 16))
    @hypothesis.settings(max_examples=25, deadline=None)
    def test_paged_kernel_bit_identical_property(pos, quantized, seed):
        """Property form: random per-row positions x random page-table
        permutations x fp/int8 — always bit-identical to the contiguous
        kernel."""
        _check_paged_kernel(pos, quantized, seed=seed)


def test_paged_kernel_fp_cushion_matches_oracle():
    """fp pool + cushion block: the paged kernel folds the cushion after
    the pages (the contiguous kernel folds it first), so the gate is the
    gather oracle, not bit-identity."""
    kp, vp, table = _paginate(_KF, _VF, 3)
    posv = jnp.asarray([_M, -1, _SMAX - 1, 33], jnp.int32)
    out = flash_decode_paged(_Q, kp, vp, table, posv, kc=_KC, vc=_VC,
                             interpret=True)
    ref = R.flash_decode_paged_ref(_Q, kp, vp, table, posv, kc=_KC, vc=_VC)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Allocator invariants (host-side, no jax)
# ---------------------------------------------------------------------------

def test_page_pool_reserve_release_accounting():
    """Admission reserves the full worst case up front (so decode can never
    exhaust mid-flight), lazy mapping draws down the reservation, release
    returns every page, and the scratch page is never handed out."""
    pool = PagePool(n_slots=2, max_seq=128, page_size=32, n_pages=6,
                    cushion_m=3)
    # need 96 positions -> pages [0, 3); prefill writes 40 -> pages [0, 2)
    scatter = pool.admit(0, prefill_end=40, need=96)
    assert scatter is not None and pool.available() == 2
    owned = set(np.asarray(pool.table[0])[np.asarray(pool.table[0]) > 0])
    assert len(owned) == 2 and 0 not in owned
    # second identical admission exceeds 5 content pages -> backpressure
    assert pool.admit(1, prefill_end=40, need=96) is None
    pool.ensure_mapped(0, 64)           # draw the reserved decode page
    assert pool.reserved == 0 and pool.available() == 2
    pool.release(0)
    assert pool.available() == 5 and not pool.table[0].any()
    assert pool.refs[0] == 1            # scratch pinned forever
    # released pages host the next admission
    assert pool.admit(1, prefill_end=40, need=96) is not None


# ---------------------------------------------------------------------------
# Scheduler: paged pool == static Engine, token for token
# ---------------------------------------------------------------------------

def _setup(arch="paper_tiny"):
    cfg = (get_config(arch) if arch == "paper_tiny"
           else reduced(get_config(arch), dtype="float32"))
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    cushion = api.extract_cushion(params, jnp.asarray([1, 2, 3], jnp.int32),
                                  None, QN)
    return api, params, cushion


@pytest.mark.parametrize("kv_dtype", [None, "int8"], ids=["fp", "int8"])
def test_paged_scheduler_matches_engine(kv_dtype):
    """A recycling trace through the paged pool (page_size 32, per-slot
    page tables, batch-free cushion) is token-for-token identical to the
    per-request static Engine — fp and int8 (whose per-slot scale leaves
    stay densely slotted next to the paged KV leaves)."""
    api, params, cushion = _setup()
    budgets = [5, 3, 6, 4, 5]
    lens = [20, 26]
    reqs = [Request(uid=i, batch=api.make_batch(jax.random.PRNGKey(100 + i),
                                                1, lens[i % 2]),
                    max_new_tokens=n)
            for i, n in enumerate(budgets)]
    ce = ContinuousEngine(api, params, QN, n_slots=2, max_seq=128,
                          cushion=cushion, kv_dtype=kv_dtype, paged=True,
                          page_size=32)
    outs = ce.run(reqs)
    assert ce.stats.recycles >= 1, "trace must exercise page recycling"
    assert ce.cache["k"].shape[1] == ce.n_pages, \
        "paged pool must hold flat pages, not per-slot rows"

    eng = Engine(api, params, QN, cushion=cushion, max_seq=128,
                 kv_dtype=kv_dtype)
    for req, out in zip(reqs, outs):
        ref = eng.generate(req.batch, req.max_new_tokens).tokens[0]
        np.testing.assert_array_equal(out.tokens, ref)
    g = ce.stats
    assert g.pages_total == ce.n_pages and g.pages_free == g.pages_total - 1
    assert g.cushion_page_refs == 1     # pool's pinned ref, no live slots


def test_page_table_syncs_flat_during_pure_decode():
    """The host->device page-table mirror runs only on actual table
    mutation: across a pure-decode window inside one page (no new
    mappings, no admissions, no releases) the ``page_table_syncs`` gauge
    stays flat, and crossing a page boundary costs exactly one sync —
    not one per step. Releasing an already-empty row is not a mutation."""
    api, params, cushion = _setup()
    ce = ContinuousEngine(api, params, QN, n_slots=3, max_seq=256,
                          cushion=cushion, paged=True, page_size=64)
    ce.start()
    for uid in range(2):
        assert ce.try_admit(Request(
            uid=uid, batch=api.make_batch(jax.random.PRNGKey(uid), 1, 20),
            max_new_tokens=50))
    ce.step()           # flushes the admission mutations
    base = ce.stats.page_table_syncs
    assert base >= 1
    # positions 24.. stay inside page 0 (64 positions) for many steps
    for _ in range(10):
        ce.step()
    assert ce.stats.page_table_syncs == base, \
        "pure-decode steps inside a mapped page must not re-sync the table"
    # decode up to the page-0/page-1 boundary: exactly one more sync for
    # the window that maps the new page (both slots map it the same step)
    while int(ce._hpos.max()) < 64:
        ce.step()
    ce.step()
    assert ce.stats.page_table_syncs == base + 1, \
        "a page-boundary crossing costs one sync, not one per step"
    # releasing a never-admitted row is a no-op: no dirty, no gauge drift
    assert not ce._pool.dirty
    gauges_before = ce._pool.gauges()
    ce._pool.release(ce.n_slots - 1)        # slot 2 never held a request
    assert not ce._pool.dirty, \
        "empty-row release must not mark the table dirty"
    assert ce._pool.gauges() == gauges_before


def test_recycle_never_copies_cushion_block():
    """The refcounted cushion lives once, batch-free, outside the page
    store: admission, decode, retirement and re-admission into the recycled
    slot all serve from the SAME device buffers — no per-slot copy, no
    re-write on recycle (the dense pool re-scattered the cushion into every
    admitted row)."""
    api, params, cushion = _setup()
    ce = ContinuousEngine(api, params, QN, n_slots=1, max_seq=128,
                          cushion=cushion, paged=True, page_size=32)
    k0, v0 = ce.cushion_block["kc"], ce.cushion_block["vc"]
    mk = lambda uid: Request(
        uid=uid, batch=api.make_batch(jax.random.PRNGKey(uid), 1, 12),
        max_new_tokens=3)
    assert ce.try_admit(mk(0))
    assert ce.stats.cushion_page_refs == 2      # pool ref + live slot
    while ce.live_count:
        ce.step()
    assert ce.stats.cushion_page_refs == 1
    assert ce.try_admit(mk(1))                  # recycled slot, no copy
    ce.step()
    assert ce.cushion_block["kc"] is k0 and ce.cushion_block["vc"] is v0
    assert ce.stats.recycles >= 1


def test_prefix_cache_hit_skips_prefill_token_for_token():
    """Requests repeating a prompt stem map the donor's pages read-only
    and prefill only the tail — greedy outputs stay token-for-token
    identical to the full-prefill static Engine, and the hit/miss counters
    prove the stem pages were actually shared."""
    api, params, cushion = _setup()
    base = np.asarray(api.make_batch(jax.random.PRNGKey(3), 1, 64)["tokens"])
    reqs = []
    for i in range(4):
        t = np.array(np.asarray(
            api.make_batch(jax.random.PRNGKey(50 + i), 1, 64)["tokens"]))
        t[:, :62] = base[:, :62]        # two full 32-pages under m=3
        reqs.append(Request(uid=i, batch={"tokens": jnp.asarray(t)},
                            max_new_tokens=4))
    ce = ContinuousEngine(api, params, QN, n_slots=2, max_seq=128,
                          cushion=cushion, paged=True, page_size=32,
                          prefix_cache=True)
    outs = ce.run(reqs)
    assert ce.stats.prefix_hits >= 1 and ce.stats.prefix_misses >= 1
    assert ce.stats.pages_shared == 0   # all released at end of trace
    eng = Engine(api, params, QN, cushion=cushion, max_seq=128)
    for req, out in zip(reqs, outs):
        ref = eng.generate(req.batch, req.max_new_tokens).tokens[0]
        np.testing.assert_array_equal(out.tokens, ref)


def test_prefix_cache_rejects_int8_pool():
    api, params, cushion = _setup()
    with pytest.raises(ValueError, match="fp pages"):
        ContinuousEngine(api, params, QN, n_slots=1, max_seq=128,
                         cushion=cushion, kv_dtype="int8", paged=True,
                         page_size=32, prefix_cache=True)


def test_paged_rejects_family_without_pageable_cache():
    """A family whose cache has no sequence-major KV leaves (pure SSM:
    recurrent state, nothing paged) gets a clear rejection, not a cryptic
    scatter failure."""
    cfg = reduced(get_config("xlstm-350m"), dtype="float32")
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="pageable"):
        ContinuousEngine(api, params, QN, n_slots=1, max_seq=128,
                         paged=True, page_size=32)


def test_paged_pool_backpressures_then_admits():
    """Page exhaustion behaves exactly like a full slot pool: try_admit
    returns False (the caller requeues), and succeeds once a retirement
    returns pages to the free list."""
    api, params, cushion = _setup()
    # 5 content pages: one admission (prompt 12 + budget 3 + m=3 -> 18
    # positions -> 3 pages of 8... use page_size 32: 1 page + 0 reserve)
    ce = ContinuousEngine(api, params, QN, n_slots=2, max_seq=128,
                          cushion=cushion, paged=True, page_size=32,
                          n_pages=2)
    mk = lambda uid: Request(
        uid=uid, batch=api.make_batch(jax.random.PRNGKey(uid), 1, 12),
        max_new_tokens=3)
    assert ce.try_admit(mk(0))
    assert not ce.try_admit(mk(1)), \
        "second admission must backpressure on the single content page"
    while ce.live_count:
        ce.step()
    assert ce.try_admit(mk(1))          # retirement returned the page


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >= 2 devices (XLA host device count)")
def test_paged_tp2_matches_unsharded():
    """tp=2 paged pool (pages sharded on the heads axis, page table and
    cushion replicated) serves the same trace token-for-token as the
    unsharded paged engine."""
    from repro.launch.mesh import make_tp_mesh
    api, params, cushion = _setup()
    reqs = [Request(uid=i, batch=api.make_batch(jax.random.PRNGKey(100 + i),
                                                1, 20),
                    max_new_tokens=4)
            for i in range(3)]
    kw = dict(n_slots=2, max_seq=128, cushion=cushion, paged=True,
              page_size=32)
    ce1 = ContinuousEngine(api, params, QN, **kw)
    ce2 = ContinuousEngine(api, params, QN, mesh=make_tp_mesh(2), **kw)
    for o1, o2 in zip(ce1.run(reqs), ce2.run(reqs)):
        np.testing.assert_array_equal(o1.tokens, o2.tokens)
