"""CushionCache behaviour tests (paper §4): greedy search, prefix tuning,
and the end-to-end effect on a model with planted activation outliers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CushionConfig, QuantConfig, get_config
from repro.core import cushioncache as CC
from repro.models import transformer as T
from repro.models.registry import build

QD = QuantConfig(mode="pt_dynamic")


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("paper_tiny")
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    return api, params


def _sample(api, i, n=32):
    return api.make_batch(jax.random.PRNGKey(1000 + i), 1, n)


def test_qerr_fn_excludes_prefix(tiny):
    api, params = tiny
    fn = CC.make_qerr_fn(api, QD)
    b = _sample(api, 0)
    e0 = float(fn(params, jnp.asarray([], jnp.int32), b))
    e1 = float(fn(params, jnp.asarray([3, 7], jnp.int32), b))
    assert np.isfinite(e0) and np.isfinite(e1)


def test_batched_qerr_matches_single(tiny):
    api, params = tiny
    b = _sample(api, 1)
    single = CC.make_qerr_fn(api, QD)
    batched = CC.make_batched_qerr_fn(api, QD)
    prefixes = jnp.asarray([[1, 2], [9, 4]], jnp.int32)
    out = np.asarray(batched(params, prefixes, b))
    for i in range(2):
        # vmap changes fp reduction order; agreement to ~0.5% is expected
        np.testing.assert_allclose(out[i],
                                   float(single(params, prefixes[i], b)),
                                   rtol=5e-3)


def test_greedy_search_runs_and_stops(tiny):
    api, params = tiny
    ccfg = CushionConfig(max_prefix_len=3, tau=0.999, n_candidates=8,
                         seed_tokens=(1,))
    res = CC.greedy_search(api, params, lambda i: _sample(api, i), QD, ccfg,
                           jax.random.PRNGKey(0), chunk=8, verbose=False)
    assert 1 <= len(res.prefix_ids) <= 3
    assert res.history  # at least one iteration evaluated


def test_prefix_tuning_reduces_objective(tiny):
    api, params = tiny
    ccfg = CushionConfig(tune_steps=30, tune_lr=3e-2, lam=0.01)
    cush0 = api.cushion_zeros(4)
    fixed = api.make_batch(jax.random.PRNGKey(2000), 2, 32)

    def batches():
        while True:
            yield fixed   # fixed batch: the objective must go down

    res = CC.prefix_tune(api, params, cush0, batches(), QD, ccfg,
                         verbose=False)
    first = np.mean([r["loss"] for r in res.log[:3]])
    last = np.mean([r["loss"] for r in res.log[-3:]])
    assert last < first


def planted_outlier_params(api, rng):
    """Plant a massive-activation pathway: a huge bias direction in layer-0
    MLP down-projection creates persistent outlier channels downstream —
    reproducing the paper's 10^4:1 top-1:median pathology."""
    params = api.init_params(rng)
    w = params["layers"]["mlp"]["w_down"]
    w = w.at[0, :8, 5].set(300.0)     # layer 0, few rows -> channel 5
    params["layers"]["mlp"]["w_down"] = w
    return params


def test_cushion_reduces_qerr_on_outlier_model(tiny):
    """End-to-end: on an outlier-planted model, a tuned cushion lowers the
    per-tensor quantization error of subsequent tokens (the paper's claim)."""
    api, _ = tiny
    params = planted_outlier_params(api, jax.random.PRNGKey(0))
    b = _sample(api, 3, n=48)
    qerr_fn = CC.make_qerr_fn(api, QD)
    base = float(qerr_fn(params, jnp.asarray([], jnp.int32), b))

    # tune_lr at the config default: the activation-range objective is a
    # sharp/noisy landscape around the greedy optimum — per-coordinate Adam
    # steps of 3e-2 overshoot it and walk the cushion away from the sink
    # configuration the greedy stage found (loss visibly diverges)
    ccfg = CushionConfig(max_prefix_len=4, tau=1.0, n_candidates=16,
                         tune_steps=30, tune_lr=1e-3, lam=0.1,
                         seed_tokens=(1,))

    def batches():
        i = 0
        while True:
            yield api.make_batch(jax.random.PRNGKey(3000 + i), 2, 48)
            i += 1

    cushion, sr, tr = CC.discover(api, params, lambda i: _sample(api, i, 48),
                                  batches(), QD, ccfg,
                                  jax.random.PRNGKey(1), verbose=False)
    _, taps = api.forward(params, b, QD, cushion=cushion, collect=True)
    cushioned = float(T.total_qerr(taps))
    assert cushioned < base, (cushioned, base)


def test_extract_cushion_families():
    for arch in ["xlstm-350m", "jamba-v0.1-52b", "whisper-base"]:
        from repro.configs import reduced
        cfg = reduced(get_config(arch), dtype="float32")
        api = build(cfg)
        params = api.init_params(jax.random.PRNGKey(0))
        cush = api.extract_cushion(params, jnp.asarray([1, 2], jnp.int32),
                                   None, QuantConfig(mode="none"))
        batch = api.make_batch(jax.random.PRNGKey(1), 2, 12)
        logits, _ = api.forward(params, batch, QuantConfig(mode="none"),
                                cushion=cush)
        assert not bool(jnp.isnan(logits).any()), arch
