"""End-to-end behaviour tests: train -> calibrate -> quantize -> serve,
plus the chunked-flash-attention equivalence the long-context paths rely on.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CushionConfig, QuantConfig, get_config
from repro.core.calibration import calibrate
from repro.data.pipeline import Pipeline, SyntheticCorpus
from repro.models import common as C
from repro.models.registry import build
from repro.serving.engine import Engine
from repro.train.trainer import eval_ppl, make_train_step, make_optimizer


@pytest.fixture(scope="module")
def trained():
    """Train paper_tiny briefly so perplexity deltas are meaningful."""
    from repro.configs import RunConfig
    cfg = get_config("paper_tiny")
    api = build(cfg)
    run = RunConfig(model=cfg, seq_len=64, global_batch=8, lr=3e-3,
                    train_steps=150, warmup_steps=10)
    corpus = SyntheticCorpus(cfg.vocab_size, seed=0)
    pipe = Pipeline(corpus, batch=8, seq_len=64, seed=0)
    params = api.init_params(jax.random.PRNGKey(0))
    opt = make_optimizer(run)
    st = opt.init(params)
    step = jax.jit(make_train_step(api, run, opt))
    for i in range(150):
        b = {k: jnp.asarray(v) for k, v in pipe.get_batch(i).items()}
        params, st, m = step(params, st, b)
    return api, params, pipe


def test_training_learns(trained):
    api, params, pipe = trained
    evalb = [{k: jnp.asarray(v) for k, v in pipe.get_batch(9000 + i).items()}
             for i in range(4)]
    ppl = eval_ppl(api, params, evalb, QuantConfig(mode="none"))
    assert ppl < 100, ppl    # vocab 512; untrained ~512


def test_static_quant_with_calibration(trained):
    api, params, pipe = trained
    qs = QuantConfig(mode="pt_static")
    cal = [{k: jnp.asarray(v) for k, v in pipe.get_batch(8000 + i).items()}
           for i in range(3)]
    scales, _ = calibrate(api, params, cal, qs)
    evalb = [{k: jnp.asarray(v) for k, v in pipe.get_batch(9000 + i).items()}
             for i in range(4)]
    ppl_fp = eval_ppl(api, params, evalb, QuantConfig(mode="none"))
    ppl_q = eval_ppl(api, params, evalb, qs, scales=scales)
    assert ppl_q < ppl_fp * 3    # W8A8 shouldn't destroy a tiny clean model


def test_engine_generates(trained):
    api, params, pipe = trained
    b = {k: jnp.asarray(v) for k, v in pipe.get_batch(7000).items()}
    eng = Engine(api, params, QuantConfig(mode="none"), max_seq=128)
    res = eng.generate(b, 6)
    assert res.tokens.shape == (8, 6)
    assert res.ttft_ms > 0 and res.tpot_ms > 0


def test_engine_with_cushion_and_static_quant(trained):
    api, params, pipe = trained
    qs = QuantConfig(mode="pt_static")
    cushion = api.extract_cushion(params, jnp.asarray([1, 2], jnp.int32),
                                  None, QuantConfig(mode="none"))
    cal = [{k: jnp.asarray(v) for k, v in pipe.get_batch(8000 + i).items()}
           for i in range(2)]
    scales, _ = calibrate(api, params, cal, qs, cushion=cushion)
    b = {k: jnp.asarray(v) for k, v in pipe.get_batch(7001).items()}
    eng = Engine(api, params, qs, cushion=cushion, scales=scales,
                 max_seq=128)
    res = eng.generate(b, 4)
    assert res.tokens.shape == (8, 4)


@pytest.mark.parametrize("S,T,prefix", [(64, 64, 0), (100, 107, 7)])
def test_flash_jnp_equals_dense(S, T, prefix):
    cfg = get_config("paper_tiny")
    rng = np.random.RandomState(S)
    q = jnp.asarray(rng.randn(2, S, 8, 32).astype(np.float32))
    k = jnp.asarray(rng.randn(2, T, 4, 32).astype(np.float32))
    v = jnp.asarray(rng.randn(2, T, 4, 32).astype(np.float32))
    i = jnp.arange(S)[:, None]
    j = jnp.arange(T)[None, :]
    mask = (j < prefix) | (j <= i + prefix)
    ref = C._sdpa_dense(q, k, v, mask, cfg)
    out = C.flash_attention_jnp(q, k, v, cfg, causal=True, prefix_len=prefix,
                                q_chunk=32, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5,
                               atol=3e-5)


def test_train_resume_determinism(tmp_path):
    """Checkpoint/restart produces the same params as an uninterrupted run
    (fault-tolerance requirement)."""
    from repro.launch.train import main as train_main
    out1 = train_main(["--arch", "paper_tiny", "--steps", "12", "--batch",
                       "2", "--seq", "32", "--save-every", "6",
                       "--ckpt-dir", str(tmp_path / "a")])
    # interrupted run: 6 steps, then resume to 12
    train_main(["--arch", "paper_tiny", "--steps", "6", "--batch", "2",
                "--seq", "32", "--save-every", "6",
                "--ckpt-dir", str(tmp_path / "b")])
    out2 = train_main(["--arch", "paper_tiny", "--steps", "12", "--batch",
                       "2", "--seq", "32", "--save-every", "6",
                       "--ckpt-dir", str(tmp_path / "b"), "--resume"])
    p1 = out1[0]["params"]
    p2 = out2[0]["params"]
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)
