"""Chunked admission prefill (``ContinuousEngine(chunk_tokens=...)``).

The contract under test: splitting an admission prefill into per-step
chunks changes WHEN prompt tokens are processed, never WHAT the request
decodes —

* Token-for-token parity with blocking admission across the serving
  matrix: dense + paged pools, fp + int8 KV, slot recycling, tp=2
  (guarded on host device count), and a prefix-cache hit landing while
  another stream is mid-flight. The chunked path stages the prompt in a
  B=1 fp row and finalizes through the SAME admit scatter (and, int8, the
  same whole-prompt scale calibration) as a blocking admission, so parity
  is bitwise, not approximate.
* A hypothesis property at the model layer: ANY split of the prompt into
  chunk-resumed ``prefill(pos_offset=...)`` calls yields final-token
  logits identical up to GEMM reduction-order rounding (XLA picks its
  reduction strategy by chunk shape) with EXACT greedy argmax — masked
  softmax terms are exact zeros, so chunk boundaries cannot change which
  token decodes, which is what the bitwise engine-level gates assert.
* Scheduler bookkeeping: short prompts bypass streaming, ``cancel`` kills
  a mid-stream request, and ``chunk_tokens`` is validated/bucketed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import QuantConfig, get_config
from repro.models.registry import build
from repro.serving import ContinuousEngine, Engine, Request
from repro.serving.engine import bucket_steps

try:                    # property tests degrade to the deterministic cases
    import hypothesis
    import hypothesis.strategies as st
except ImportError:     # pragma: no cover
    hypothesis = st = None

QN = QuantConfig(mode="none")


def _setup():
    cfg = get_config("paper_tiny")
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    cushion = api.extract_cushion(params, jnp.asarray([1, 2, 3], jnp.int32),
                                  None, QN)
    return api, params, cushion


def _recycling_trace(api, n=5):
    """Mixed-length trace: 40-token prompts stream (chunk budget 16), the
    12-token prompts take the blocking path, and n > n_slots forces slot
    recycling mid-trace."""
    return [Request(uid=i,
                    batch=api.make_batch(jax.random.PRNGKey(100 + i), 1,
                                         [40, 12][i % 2]),
                    max_new_tokens=[5, 3, 6, 4, 5][i % 5])
            for i in range(n)]


def _run_pair(api, params, cushion, reqs, **kw):
    blocking = ContinuousEngine(api, params, QN, n_slots=2, max_seq=128,
                                cushion=cushion, **kw)
    chunked = ContinuousEngine(api, params, QN, n_slots=2, max_seq=128,
                               cushion=cushion, chunk_tokens=16, **kw)
    out_b = blocking.run(reqs)
    out_c = chunked.run(reqs)
    assert chunked.stats.prefill_chunks >= 3, \
        "long prompts must actually stream (3 chunks per 40-token prompt)"
    assert chunked.stats.admitted == len(reqs)
    assert [o.uid for o in out_b] == [o.uid for o in out_c]
    for a, b in zip(out_b, out_c):
        np.testing.assert_array_equal(a.tokens, b.tokens)
    return chunked


# ---------------------------------------------------------------------------
# Parity matrix: chunked == blocking, token for token
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pool,kv", [
    ("dense", None), ("dense", "int8"), ("paged", None), ("paged", "int8"),
], ids=["dense-fp", "dense-int8", "paged-fp", "paged-int8"])
def test_chunked_matches_blocking(pool, kv):
    """The core invariant across the pool matrix, with slot recycling: a
    chunk-streamed admission retires with exactly the tokens a blocking
    admission produces. int8 pools stage fp and requantize once at
    finalize, so per-slot scales calibrate over the whole prompt exactly
    like the blocking path."""
    api, params, cushion = _setup()
    kw = {"kv_dtype": kv}
    if pool == "paged":
        kw.update(paged=True, page_size=32)
    _run_pair(api, params, cushion, _recycling_trace(api), **kw)


def test_chunked_matches_static_engine():
    """Transitive oracle: chunked continuous serving reproduces the
    per-request static Engine (prefill-all-at-once, B=1) token for token —
    the same gate the blocking scheduler is held to."""
    api, params, cushion = _setup()
    reqs = _recycling_trace(api)
    ce = ContinuousEngine(api, params, QN, n_slots=2, max_seq=128,
                          cushion=cushion, chunk_tokens=16)
    outs = ce.run(reqs)
    eng = Engine(api, params, QN, cushion=cushion, max_seq=128)
    for req, out in zip(reqs, outs):
        ref = eng.generate(req.batch, req.max_new_tokens).tokens[0]
        np.testing.assert_array_equal(out.tokens, ref)


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >= 2 devices (XLA host device count)")
def test_chunked_tp2_matches_unsharded():
    """tp=2 chunked admission (staging row sharded like the pool's heads
    axis, chunk-resume reads the sharded prefix back) serves the trace
    token-for-token like the unsharded chunked engine."""
    from repro.launch.mesh import make_tp_mesh
    api, params, cushion = _setup()
    reqs = _recycling_trace(api, n=3)
    kw = dict(n_slots=2, max_seq=128, cushion=cushion, chunk_tokens=16)
    ce1 = ContinuousEngine(api, params, QN, **kw)
    ce2 = ContinuousEngine(api, params, QN, mesh=make_tp_mesh(2), **kw)
    for o1, o2 in zip(ce1.run(reqs), ce2.run(reqs)):
        np.testing.assert_array_equal(o1.tokens, o2.tokens)


def test_prefix_cache_hit_mid_chunk_stream():
    """A donor request registers its prompt-stem pages while a long
    chunked stream is still mid-flight; a later long request sharing the
    stem maps the donor's pages (prefix hit) and streams only the tail —
    all three token-for-token against the static Engine."""
    api, params, cushion = _setup()
    base = np.asarray(api.make_batch(jax.random.PRNGKey(3), 1, 32)["tokens"])
    long_a = api.make_batch(jax.random.PRNGKey(50), 1, 80)
    sharer = np.array(np.asarray(
        api.make_batch(jax.random.PRNGKey(51), 1, 80)["tokens"]))
    sharer[:, :30] = base[:, :30]   # page 0 = cushion(3) + 29 prompt tokens
    reqs = [
        # uid 0: long unrelated prompt -> streams first, holds a slot
        Request(uid=0, batch=long_a, max_new_tokens=6),
        # uid 1: short donor (32 = one chunk budget) -> blocking admission
        # registers the stem while uid 0 is still mid-stream
        Request(uid=1, batch={"tokens": jnp.asarray(base)},
                max_new_tokens=3, arrival_s=0.0),
        # uid 2: shares the stem -> must hit the registry, stream the tail
        Request(uid=2, batch={"tokens": jnp.asarray(sharer)},
                max_new_tokens=4, arrival_s=0.0),
    ]
    ce = ContinuousEngine(api, params, QN, n_slots=3, max_seq=128,
                          cushion=cushion, paged=True, page_size=32,
                          prefix_cache=True, chunk_tokens=32)
    outs = ce.run(reqs)
    assert ce.stats.prefix_hits >= 1, "sharer must hit the stem registry"
    assert ce.stats.prefill_chunks >= 3
    eng = Engine(api, params, QN, cushion=cushion, max_seq=128)
    for req, out in zip(reqs, outs):
        ref = eng.generate(req.batch, req.max_new_tokens).tokens[0]
        np.testing.assert_array_equal(out.tokens, ref)


# ---------------------------------------------------------------------------
# Scheduler bookkeeping
# ---------------------------------------------------------------------------

def test_short_prompts_bypass_streaming():
    """Prompts that fit one chunk budget admit blocking — zero streamed
    chunks, no staging row, identical outputs."""
    api, params, cushion = _setup()
    reqs = [Request(uid=i, batch=api.make_batch(jax.random.PRNGKey(i), 1, 12),
                    max_new_tokens=3) for i in range(3)]
    ce = ContinuousEngine(api, params, QN, n_slots=2, max_seq=128,
                          cushion=cushion, chunk_tokens=16)
    outs = ce.run(reqs)
    assert len(outs) == 3
    assert ce.stats.prefill_chunks == 0
    assert ce.stats.admitted == 3


def test_cancel_mid_stream_frees_slot():
    """cancel() on a PREFILLING uid drops the stream without a result and
    frees the slot for the next admission."""
    api, params, cushion = _setup()
    ce = ContinuousEngine(api, params, QN, n_slots=1, max_seq=128,
                          cushion=cushion, chunk_tokens=16)
    ce.start()
    long_req = Request(uid=0, batch=api.make_batch(jax.random.PRNGKey(0),
                                                   1, 48),
                       max_new_tokens=4)
    assert ce.try_admit(long_req)
    assert ce.prefilling == 1 and ce.is_prefilling(0)
    ce.step()                           # one chunk in
    assert ce.prefilling == 1
    assert ce.cancel(0)
    assert ce.prefilling == 0 and not ce.is_prefilling(0)
    assert ce.stats.canceled == 1
    short = Request(uid=1, batch=api.make_batch(jax.random.PRNGKey(1), 1, 8),
                    max_new_tokens=2)
    assert ce.try_admit(short), "canceled stream must free its slot"
    while ce.live_count:
        ce.step()
    outs = ce.pop_finished()
    assert [o.uid for o in outs] == [1]


def test_chunk_tokens_validation_and_bucketing():
    api, params, cushion = _setup()
    with pytest.raises(ValueError, match="chunk_tokens"):
        ContinuousEngine(api, params, QN, n_slots=1, max_seq=128,
                         cushion=cushion, chunk_tokens=0)
    ce = ContinuousEngine(api, params, QN, n_slots=1, max_seq=128,
                          cushion=cushion, chunk_tokens=13)
    assert ce.chunk_tokens == bucket_steps(13)  # power-of-two budget


# ---------------------------------------------------------------------------
# Model layer: any chunk split is bit-identical
# ---------------------------------------------------------------------------

_S = 20     # prompt length for the split property (keeps sdpa off the
            # flash path so every split size shares one attention algorithm)


def _split_prefill(api, params, cushion, toks, cuts):
    """Prefill ``toks`` in chunks [0:c1), [c1:c2), ... via pos_offset
    resume; returns (final-token logits, staged cache row)."""
    m = int(cushion["kv"]["k"].shape[1]) if cushion is not None else 0
    cache = api.init_cache(1, 64)
    bounds = [0] + sorted(cuts) + [_S]
    logits = None
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        if lo == hi:
            continue
        chunk = {"tokens": toks[:, lo:hi]}
        if lo == 0:
            logits, cache, _ = api.prefill(params, chunk, cache, QN,
                                           cushion=cushion)
        else:
            logits, cache, _ = api.prefill(params, chunk, cache, QN,
                                           pos_offset=m + lo)
    return logits[:, -1] if logits.ndim == 3 else logits, cache


def _check_split(api, params, cushion, cuts):
    """The split invariant, at the strongest level the backend admits:
    XLA's GEMM reduction strategy varies with the M (chunk-length) shape,
    so logits across different splits agree to reduction-order rounding
    (~1e-6 relative), NOT bitwise — greedy argmax, and therefore every
    engine-level parity gate in this file, is exact. Both are asserted."""
    toks = api.make_batch(jax.random.PRNGKey(9), 1, _S)["tokens"]
    ref_logits, ref_cache = _split_prefill(api, params, cushion, toks, [])
    out_logits, out_cache = _split_prefill(api, params, cushion, toks, cuts)
    np.testing.assert_allclose(np.asarray(out_logits),
                               np.asarray(ref_logits),
                               rtol=2e-5, atol=2e-5)
    assert int(jnp.argmax(out_logits, -1)[0]) == \
        int(jnp.argmax(ref_logits, -1)[0])
    m = int(cushion["kv"]["k"].shape[1]) if cushion is not None else 0
    for key in ("k", "v"):
        np.testing.assert_allclose(
            np.asarray(out_cache[key][:, :, :m + _S]),
            np.asarray(ref_cache[key][:, :, :m + _S]),
            rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("cuts", [[1], [7], [_S - 1], [5, 10, 15], [3, 4]])
def test_split_prefill_bit_identical_cases(cuts):
    """Deterministic splits (always run): chunk-resumed prefill matches
    the one-shot prefill's logits and staged KV to reduction-order
    rounding, with exact greedy argmax — the invariant the whole chunked
    admission path rests on."""
    api, params, cushion = _setup()
    _check_split(api, params, cushion, cuts)


if hypothesis is not None:
    @hypothesis.given(st.sets(st.integers(1, _S - 1), max_size=4))
    @hypothesis.settings(max_examples=15, deadline=None)
    def test_split_prefill_bit_identical_property(cuts):
        """ANY set of split points yields the same prefill up to
        reduction-order rounding with exact greedy argmax: masked softmax
        terms are exact zeros and chunk boundaries only change which call
        computes a row, never its decoded token."""
        api, params, cushion = _setup()
        _check_split(api, params, cushion, sorted(cuts))


# ---------------------------------------------------------------------------
# Adaptive budget (chunk_tokens="auto")
# ---------------------------------------------------------------------------

def test_auto_budget_tracks_slot_pressure():
    """The adaptive budget slides from the max (idle pool: admit in one
    bite, best TTFT) down toward the floor as decode slots fill (busy
    pool: small bites protect the decoders' TPOT), always landing on the
    same power-of-two buckets as a fixed budget."""
    from repro.serving.scheduler import _AUTO_CHUNK_MAX, _AUTO_CHUNK_MIN
    api, params, cushion = _setup()
    ce = ContinuousEngine(api, params, QN, n_slots=4, max_seq=128,
                          cushion=cushion, chunk_tokens="auto")
    ce.start()
    assert ce.chunk_auto
    assert ce._chunk_budget() == _AUTO_CHUNK_MAX      # empty pool
    budgets = [ce._chunk_budget()]
    for i in range(4):
        assert ce.try_admit(Request(
            uid=i, batch=api.make_batch(jax.random.PRNGKey(i), 1, 8),
            max_new_tokens=30))
        budgets.append(ce._chunk_budget())
    assert budgets == sorted(budgets, reverse=True), \
        f"budget must shrink monotonically with occupancy: {budgets}"
    assert budgets[-1] == bucket_steps(_AUTO_CHUNK_MIN)  # full pool: floor
    # draining the pool grows the budget back
    while ce.live_count:
        ce.step()
    ce.pop_finished()
    assert ce._chunk_budget() == _AUTO_CHUNK_MAX


def test_auto_budget_streams_more_under_load_with_parity():
    """The TTFT/TPOT trade-off direction: the same long prompt admits in
    one blocking bite on an idle pool (zero streamed chunks — minimal
    TTFT) but streams in several small chunks when decode slots are busy
    (decoders keep stepping between bites — their TPOT is protected), and
    either way retires with exactly the static Engine's tokens."""
    api, params, cushion = _setup()
    long_req = lambda: Request(
        uid=99, batch=api.make_batch(jax.random.PRNGKey(50), 1, 80),
        max_new_tokens=6)

    # idle pool: budget at the max, 80-token prompt admits blocking
    idle = ContinuousEngine(api, params, QN, n_slots=4, max_seq=128,
                            cushion=cushion, chunk_tokens="auto")
    out_idle = idle.run([long_req()])
    assert idle.stats.prefill_chunks == 0

    # busy pool: three decoders live shrink the budget below the prompt
    busy = ContinuousEngine(api, params, QN, n_slots=4, max_seq=128,
                            cushion=cushion, chunk_tokens="auto")
    busy.start()
    for i in range(3):
        assert busy.try_admit(Request(
            uid=i, batch=api.make_batch(jax.random.PRNGKey(i), 1, 8),
            max_new_tokens=25))
    assert busy.try_admit(long_req())
    assert busy.is_prefilling(99), \
        "near-full pool must shrink the budget below the prompt length"
    while busy.live_count or busy.prefilling:
        busy.step()
    out_busy = [o for o in busy.pop_finished() if o.uid == 99]
    assert busy.stats.prefill_chunks >= 2

    eng = Engine(api, params, QN, cushion=cushion, max_seq=128)
    ref = eng.generate(long_req().batch, 6).tokens[0]
    np.testing.assert_array_equal(out_idle[0].tokens, ref)
    np.testing.assert_array_equal(out_busy[0].tokens, ref)


def test_auto_budget_validation():
    api, params, cushion = _setup()
    with pytest.raises(ValueError, match="chunk_tokens"):
        ContinuousEngine(api, params, QN, n_slots=1, max_seq=128,
                         cushion=cushion, chunk_tokens="adaptive")
