"""Property tests for the quantization core (paper §3)."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
hnp = pytest.importorskip("hypothesis.extra.numpy")
st = pytest.importorskip("hypothesis.strategies")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import QuantConfig
from repro.core import quantization as Q

settings = hypothesis.settings(max_examples=25, deadline=None)

floats = st.floats(min_value=-1e3, max_value=1e3, allow_nan=False,
                   width=32)


@settings
@hypothesis.given(hnp.arrays(np.float32, hnp.array_shapes(min_dims=2,
                                                          max_dims=3,
                                                          max_side=16),
                             elements=floats),
                  st.sampled_from([4, 6, 8]),
                  st.booleans())
def test_quant_roundtrip_error_bound(x, bits, symmetric):
    """|x - dq(q(x))| <= scale/2 elementwise within the clip range."""
    x = jnp.asarray(x)
    mn, mx = Q.act_minmax(x, per_token=False)
    scale, zero = Q.params_from_minmax(mn, mx, bits, symmetric)
    xq = Q.dequantize(Q.quantize(x, scale, zero, bits, symmetric),
                      scale, zero)
    # inside the representable range the error is at most half a step
    lo = Q.dequantize(jnp.asarray(Q.qrange(bits, symmetric)[0]), scale, zero)
    hi = Q.dequantize(jnp.asarray(Q.qrange(bits, symmetric)[1]), scale, zero)
    inside = (x >= lo) & (x <= hi)
    err = jnp.abs(x - xq)
    assert np.all(np.asarray(err[inside]) <= float(scale) / 2 + 1e-4)


@settings
@hypothesis.given(hnp.arrays(np.float32, (8, 16), elements=floats),
                  st.sampled_from([6, 8]))
def test_fake_quant_idempotent(x, bits):
    x = jnp.asarray(x)
    mn, mx = Q.act_minmax(x, per_token=False)
    scale, zero = Q.params_from_minmax(mn, mx, bits, False)
    y1 = Q.fake_quant(x, scale, zero, bits, False)
    y2 = Q.fake_quant(y1, scale, zero, bits, False)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)


def test_ste_gradient_identity_in_range():
    x = jnp.linspace(-0.9, 0.9, 16)
    scale = jnp.asarray(0.1)
    zero = jnp.asarray(0.0)
    g = jax.grad(lambda x: jnp.sum(Q.fake_quant(x, scale, zero, 8, True)))(x)
    np.testing.assert_allclose(np.asarray(g), np.ones(16), rtol=1e-6)


@pytest.mark.parametrize("mode", ["pt_dynamic", "ptoken_dynamic"])
def test_qdot_close_to_fp(mode):
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(4, 64).astype(np.float32))
    w = jnp.asarray(rng.randn(64, 32).astype(np.float32) * 0.1)
    qcfg = QuantConfig(mode=mode)
    out = Q.qdot(x, w, qcfg)
    rel = np.abs(np.asarray(out - x @ w)).max() / np.abs(np.asarray(x @ w)).max()
    assert rel < 0.05


def test_true_int8_matches_fake_quant():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(4, 64).astype(np.float32))
    w = jnp.asarray(rng.randn(64, 32).astype(np.float32) * 0.1)
    mn, mx = Q.act_minmax(x, False)
    scale, zero = Q.params_from_minmax(mn, mx, 8, False)
    site = Q.SiteScale(scale=scale, zero=zero)
    a = Q.qdot(x, w, QuantConfig(mode="pt_static", true_int8=True,
                                 w_group=0), site)
    b = Q.qdot(x, w, QuantConfig(mode="pt_static", true_int8=False,
                                 w_group=0), site)
    # weight quant granularity differs (per-tensor vs per-channel-group);
    # bound loosely
    rel = np.abs(np.asarray(a - b)).max() / np.abs(np.asarray(b)).max()
    assert rel < 0.1


def test_outlier_blows_up_per_tensor_quant():
    """The paper's core premise: one outlier destroys per-tensor scales."""
    rng = np.random.RandomState(0)
    x = rng.randn(16, 64).astype(np.float32)
    clean_err = float(Q.site_qerr(jnp.asarray(x),
                                  QuantConfig(mode="pt_dynamic"), None))
    x_out = x.copy()
    x_out[3, 7] = 10_000.0
    dirty_err = float(Q.site_qerr(jnp.asarray(x_out),
                                  QuantConfig(mode="pt_dynamic"), None))
    assert dirty_err > 100 * clean_err


def test_per_token_robust_to_token_outlier():
    """A token outlier wrecks the *other* tokens under per-tensor scales but
    not under per-token scales (the paper's granularity comparison)."""
    rng = np.random.RandomState(0)
    x = rng.randn(16, 64).astype(np.float32)
    x_out = x.copy()
    x_out[3, :] *= 10_000.0
    xj = jnp.asarray(x_out)

    def clean_rows_err(mode):
        per_token = mode == "ptoken_dynamic"
        mn, mx = Q.act_minmax(xj, per_token)
        scale, zero = Q.params_from_minmax(mn, mx, 8, False)
        xq = Q.dequantize(Q.quantize(xj, scale, zero, 8, False), scale, zero)
        err = np.asarray(jnp.square(xj - xq))
        return err[np.arange(16) != 3].sum()

    assert clean_rows_err("ptoken_dynamic") < clean_rows_err("pt_dynamic") / 10


def test_scales_from_stats_shapes():
    stats = {"a": {"amin": jnp.zeros((4,)), "amax": jnp.ones((4,)),
                   "absmax_ch": jnp.ones((4, 8))}}
    scales = Q.scales_from_stats(stats, QuantConfig(mode="pt_static"))
    assert scales["a"].scale.shape == (4,)
    assert scales["a"].zero.shape == (4,)


def test_prequantized_int_dot_matches_true_int_dot():
    rng = np.random.RandomState(5)
    x = jnp.asarray(rng.randn(6, 64).astype(np.float32))
    w = jnp.asarray(rng.randn(64, 32).astype(np.float32) * 0.1)
    qcfg = QuantConfig(mode="pt_static", true_int8=True)
    mn, mx = Q.act_minmax(x, False)
    scale, zero = Q.params_from_minmax(mn, mx, 8, False)
    site = Q.SiteScale(scale=scale, zero=zero)
    a = Q.qdot(x, Q.prequantize(w, qcfg), qcfg, site)
    b = Q.true_int_dot(x, w, qcfg, site)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_prequantize_tree_selects_qdot_weights_only():
    from repro.configs import get_config
    from repro.models.registry import build
    import jax
    cfg = get_config("paper_tiny")
    api = build(cfg)
    p = api.init_params(jax.random.PRNGKey(0))
    qcfg = QuantConfig(mode="pt_static", true_int8=True)
    pq = Q.prequantize_tree(p, qcfg)
    assert "w_int" in pq["layers"]["attn"]["wqkv"]
    assert pq["layers"]["attn"]["wqkv"]["w_int"].dtype == jnp.int8
    # embeddings untouched
    assert not isinstance(pq["embed"]["w"], dict)


def test_prequantized_forward_close_to_fp():
    from repro.configs import get_config
    from repro.models.registry import build
    from repro.models import transformer as T
    import jax
    cfg = get_config("paper_tiny")
    api = build(cfg)
    p = api.init_params(jax.random.PRNGKey(0))
    b = api.make_batch(jax.random.PRNGKey(1), 2, 16)
    ref, _ = api.forward(p, b, QuantConfig(mode="none"))
    qcfg = QuantConfig(mode="pt_static", true_int8=True)
    scales = T.placeholder_all_scales(cfg)
    # calibrated-ish scales: use dynamic stats per site via calibration
    from repro.core.calibration import calibrate
    scales, _ = calibrate(api, p, [b], qcfg)
    pq = Q.prequantize_tree(p, qcfg)
    out, _ = api.forward(pq, b, qcfg, scales=scales)
    rel = float(jnp.abs(out - ref).max() / jnp.abs(ref).max())
    assert rel < 0.25, rel
