"""Property tests for the quantization core (paper §3).

The hypothesis-based properties skip individually when hypothesis isn't
installed; the module must NOT importorskip at the top level — the
deterministic contract tests below (range convention, int4 round-trip,
outlier premises) have to run everywhere."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import QuantConfig
from repro.core import quantization as Q

try:
    import hypothesis
    import hypothesis.extra.numpy as hnp
    import hypothesis.strategies as st
except ImportError:     # pragma: no cover
    hypothesis = hnp = st = None

if hypothesis is not None:
    settings = hypothesis.settings(max_examples=25, deadline=None)

    floats = st.floats(min_value=-1e3, max_value=1e3, allow_nan=False,
                       width=32)

    @settings
    @hypothesis.given(hnp.arrays(np.float32,
                                 hnp.array_shapes(min_dims=2, max_dims=3,
                                                  max_side=16),
                                 elements=floats),
                      st.sampled_from([4, 6, 8]),
                      st.booleans())
    def test_quant_roundtrip_error_bound(x, bits, symmetric):
        """|x - dq(q(x))| <= scale/2 elementwise within the clip range."""
        x = jnp.asarray(x)
        mn, mx = Q.act_minmax(x, per_token=False)
        scale, zero = Q.params_from_minmax(mn, mx, bits, symmetric)
        xq = Q.dequantize(Q.quantize(x, scale, zero, bits, symmetric),
                          scale, zero)
        # inside the representable range the error is at most half a step
        lo = Q.dequantize(jnp.asarray(Q.qrange(bits, symmetric)[0]),
                          scale, zero)
        hi = Q.dequantize(jnp.asarray(Q.qrange(bits, symmetric)[1]),
                          scale, zero)
        inside = (x >= lo) & (x <= hi)
        err = jnp.abs(x - xq)
        assert np.all(np.asarray(err[inside]) <= float(scale) / 2 + 1e-4)

    @settings
    @hypothesis.given(hnp.arrays(np.float32, (8, 16), elements=floats),
                      st.sampled_from([6, 8]))
    def test_fake_quant_idempotent(x, bits):
        x = jnp.asarray(x)
        mn, mx = Q.act_minmax(x, per_token=False)
        scale, zero = Q.params_from_minmax(mn, mx, bits, False)
        y1 = Q.fake_quant(x, scale, zero, bits, False)
        y2 = Q.fake_quant(y1, scale, zero, bits, False)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-5, atol=1e-5)


def test_ste_gradient_identity_in_range():
    x = jnp.linspace(-0.9, 0.9, 16)
    scale = jnp.asarray(0.1)
    zero = jnp.asarray(0.0)
    g = jax.grad(lambda x: jnp.sum(Q.fake_quant(x, scale, zero, 8, True)))(x)
    np.testing.assert_allclose(np.asarray(g), np.ones(16), rtol=1e-6)


@pytest.mark.parametrize("mode", ["pt_dynamic", "ptoken_dynamic"])
def test_qdot_close_to_fp(mode):
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(4, 64).astype(np.float32))
    w = jnp.asarray(rng.randn(64, 32).astype(np.float32) * 0.1)
    qcfg = QuantConfig(mode=mode)
    out = Q.qdot(x, w, qcfg)
    rel = np.abs(np.asarray(out - x @ w)).max() / np.abs(np.asarray(x @ w)).max()
    assert rel < 0.05


def test_true_int8_matches_fake_quant():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(4, 64).astype(np.float32))
    w = jnp.asarray(rng.randn(64, 32).astype(np.float32) * 0.1)
    mn, mx = Q.act_minmax(x, False)
    scale, zero = Q.params_from_minmax(mn, mx, 8, False)
    site = Q.SiteScale(scale=scale, zero=zero)
    a = Q.qdot(x, w, QuantConfig(mode="pt_static", true_int8=True,
                                 w_group=0), site)
    b = Q.qdot(x, w, QuantConfig(mode="pt_static", true_int8=False,
                                 w_group=0), site)
    # weight quant granularity differs (per-tensor vs per-channel-group);
    # bound loosely
    rel = np.abs(np.asarray(a - b)).max() / np.abs(np.asarray(b)).max()
    assert rel < 0.1


def test_outlier_blows_up_per_tensor_quant():
    """The paper's core premise: one outlier destroys per-tensor scales."""
    rng = np.random.RandomState(0)
    x = rng.randn(16, 64).astype(np.float32)
    clean_err = float(Q.site_qerr(jnp.asarray(x),
                                  QuantConfig(mode="pt_dynamic"), None))
    x_out = x.copy()
    x_out[3, 7] = 10_000.0
    dirty_err = float(Q.site_qerr(jnp.asarray(x_out),
                                  QuantConfig(mode="pt_dynamic"), None))
    assert dirty_err > 100 * clean_err


def test_per_token_robust_to_token_outlier():
    """A token outlier wrecks the *other* tokens under per-tensor scales but
    not under per-token scales (the paper's granularity comparison)."""
    rng = np.random.RandomState(0)
    x = rng.randn(16, 64).astype(np.float32)
    x_out = x.copy()
    x_out[3, :] *= 10_000.0
    xj = jnp.asarray(x_out)

    def clean_rows_err(mode):
        per_token = mode == "ptoken_dynamic"
        mn, mx = Q.act_minmax(xj, per_token)
        scale, zero = Q.params_from_minmax(mn, mx, 8, False)
        xq = Q.dequantize(Q.quantize(xj, scale, zero, 8, False), scale, zero)
        err = np.asarray(jnp.square(xj - xq))
        return err[np.arange(16) != 3].sum()

    assert clean_rows_err("ptoken_dynamic") < clean_rows_err("pt_dynamic") / 10


def test_scales_from_stats_shapes():
    stats = {"a": {"amin": jnp.zeros((4,)), "amax": jnp.ones((4,)),
                   "absmax_ch": jnp.ones((4, 8))}}
    scales = Q.scales_from_stats(stats, QuantConfig(mode="pt_static"))
    assert scales["a"].scale.shape == (4,)
    assert scales["a"].zero.shape == (4,)


def test_prequantized_int_dot_matches_true_int_dot():
    rng = np.random.RandomState(5)
    x = jnp.asarray(rng.randn(6, 64).astype(np.float32))
    w = jnp.asarray(rng.randn(64, 32).astype(np.float32) * 0.1)
    qcfg = QuantConfig(mode="pt_static", true_int8=True)
    mn, mx = Q.act_minmax(x, False)
    scale, zero = Q.params_from_minmax(mn, mx, 8, False)
    site = Q.SiteScale(scale=scale, zero=zero)
    a = Q.qdot(x, Q.prequantize(w, qcfg), qcfg, site)
    b = Q.true_int_dot(x, w, qcfg, site)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_prequantize_tree_selects_qdot_weights_only():
    from repro.configs import get_config
    from repro.models.registry import build
    import jax
    cfg = get_config("paper_tiny")
    api = build(cfg)
    p = api.init_params(jax.random.PRNGKey(0))
    qcfg = QuantConfig(mode="pt_static", true_int8=True)
    pq = Q.prequantize_tree(p, qcfg)
    assert "w_int" in pq["layers"]["attn"]["wqkv"]
    assert pq["layers"]["attn"]["wqkv"]["w_int"].dtype == jnp.int8
    # embeddings untouched
    assert not isinstance(pq["embed"]["w"], dict)


def test_prequantized_forward_close_to_fp():
    from repro.configs import get_config
    from repro.models.registry import build
    from repro.models import transformer as T
    import jax
    cfg = get_config("paper_tiny")
    api = build(cfg)
    p = api.init_params(jax.random.PRNGKey(0))
    b = api.make_batch(jax.random.PRNGKey(1), 2, 16)
    ref, _ = api.forward(p, b, QuantConfig(mode="none"))
    qcfg = QuantConfig(mode="pt_static", true_int8=True)
    scales = T.placeholder_all_scales(cfg)
    # calibrated-ish scales: use dynamic stats per site via calibration
    from repro.core.calibration import calibrate
    scales, _ = calibrate(api, p, [b], qcfg)
    pq = Q.prequantize_tree(p, qcfg)
    out, _ = api.forward(pq, b, qcfg, scales=scales)
    rel = float(jnp.abs(out - ref).max() / jnp.abs(ref).max())
    assert rel < 0.25, rel


# ---------------------------------------------------------------------------
# Sub-8-bit range convention: restricted symmetric [-
# (2^(b-1)-1), 2^(b-1)-1], never the full two's-complement [-8, 7] at 4
# bits. Every quantizer routes through qrange, so fake-quant calibration
# and the true int4-packed inference path live on the same grid; these pin
# that agreement so a "use the whole nibble" change can't silently split
# the two worlds.
# ---------------------------------------------------------------------------

def test_int4_range_is_restricted_symmetric():
    assert Q.qrange(4, True) == (-7, 7)
    assert Q.qrange(4, False) == (0, 15)
    # symmetric scale divides by the restricted qmax
    scale, zero = Q.params_from_minmax(jnp.float32(-2.1), jnp.float32(2.1),
                                       4, True)
    np.testing.assert_allclose(float(scale), 2.1 / 7, rtol=1e-6)
    assert float(zero) == 0.0


@pytest.mark.parametrize("bits", [4, 6])
def test_sub8_quantizers_never_emit_full_range_min(bits):
    """quantize / fake_quant / weight_quant_int / weight_quant_int4 all
    clip to the restricted grid — -2^(b-1) never appears, even for inputs
    far below -amax*(qmax+1)/qmax (the value that would round there)."""
    cfg = QuantConfig(mode="pt_static", w_bits=bits, true_int8=True)
    rng = np.random.RandomState(bits)
    w = jnp.asarray(rng.randn(64, 16).astype(np.float32))
    w = w.at[0, 0].set(-100.0).at[1, 1].set(100.0)   # clip-range extremes
    lo = -(2 ** (bits - 1) - 1)
    wq, scale = Q.weight_quant_int(w, cfg)
    assert int(wq.min()) >= lo and int(wq.max()) <= -lo
    amax = jnp.max(jnp.abs(w))
    s, z = Q.params_from_minmax(-amax, amax, bits, True)
    assert int(Q.quantize(w, s, z, bits, True).min()) >= lo
    fq = Q.fake_quant(w, s, z, bits, True)
    assert float(fq.min()) >= lo * float(s) - 1e-6
    if bits == 4:
        wq4, s4, g = Q.weight_quant_int4(w, cfg)
        assert int(wq4.min()) >= -7 and int(wq4.max()) <= 7


def test_weight_quant_int4_roundtrips_fake_quant_bit_identically():
    """dequant(weight_quant_int4(w)) == weight_fake_quant(w) at 4 bits,
    bit-for-bit: both derive the same group amax -> restricted scale ->
    rounded grid, so fake-quant calibration statistics describe exactly
    what the packed path serves."""
    cfg = QuantConfig(mode="pt_static", w_bits=4, true_int8=True)
    rng = np.random.RandomState(0)
    for d_in in (256, 33):      # grouped (2x128) and indivisible fallback
        w = jnp.asarray(rng.randn(d_in, 24).astype(np.float32))
        wq, scale, g = Q.weight_quant_int4(w, cfg)
        dq = wq.astype(jnp.float32).reshape(d_in // g, g, 24) \
            * scale[:, None, :]
        fq = Q.weight_fake_quant(w, cfg)
        np.testing.assert_array_equal(np.asarray(dq.reshape(d_in, 24)),
                                      np.asarray(fq))
        # and the packed round-trip serves those exact integers
        np.testing.assert_array_equal(
            np.asarray(Q.unpack_int4(Q.pack_int4(wq), d_in)),
            np.asarray(wq))
