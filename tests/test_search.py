"""Greedy-search fast path (compile-once, KV-reuse candidate scoring):

* `ModelAPI.score_candidates` / `prefix_qerr` L_q equivalence against the
  reference full-forward scorer (`forward_with_token_prefix`), for dense,
  VLM, and MoE (the MoE "down"-site contract: prefix expert traffic is a
  candidate-independent additive offset in the reference scorer);
* `greedy_search` vs `greedy_search_ref` token-for-token prefix parity on
  paper_tiny (per-token dynamic quantization, where the two scorers are
  mathematically identical);
* compile-count constancy: the fast search compiles the same number of
  executables regardless of `max_prefix_len`;
* the documented fallback for families without attention-KV-only prefix
  artifacts (ssm/hybrid/encdec).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CushionConfig, QuantConfig, get_config, reduced
from repro.core import cushioncache as CC
from repro.models.registry import build
from repro.monitoring import count_compiles

QN = QuantConfig(mode="none")
QP = QuantConfig(mode="ptoken_dynamic")


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("paper_tiny")
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    return api, params


@pytest.fixture(scope="module")
def tiny_outlier(tiny):
    """paper_tiny with the planted massive-activation pathway (same surgery
    as tests/test_cushion.py) so candidate ranking is meaningful."""
    api, _ = tiny
    params = api.init_params(jax.random.PRNGKey(0))
    w = params["layers"]["mlp"]["w_down"]
    params["layers"]["mlp"]["w_down"] = w.at[0, :8, 5].set(300.0)
    return api, params


def _sample(api, i, n=32):
    return api.make_batch(jax.random.PRNGKey(1000 + i), 1, n)


def _ref_scores(api, params, prefix, cands, batch, qcfg):
    batched = CC.make_batched_qerr_fn(api, qcfg)
    prefixes = jnp.asarray([list(prefix) + [int(c)] for c in cands],
                           jnp.int32)
    return np.asarray(batched(params, prefixes, batch))


@pytest.mark.parametrize("qcfg,rtol", [(QN, 1e-4), (QP, 2e-3)],
                         ids=["none", "ptoken"])
def test_score_candidates_matches_full_forward(tiny, qcfg, rtol):
    """KV-reuse scoring == full-forward scoring for position-local quant
    modes (clean / per-token dynamic), with a padded prefix and live
    length. (Per-token fake-quant rounds at .5 boundaries, so last-ulp
    scale differences can flip single elements — hence the looser rtol.)"""
    api, params = tiny
    batch = _sample(api, 0)
    prefix = [1, 7]
    padded = jnp.asarray(prefix + [0, 0], jnp.int32)     # max_m = 4, live 2
    cands = np.asarray([5, 9, 100, 200], np.int32)

    pkv = api.prefix_kv(params, padded, qcfg)
    fast = np.asarray(api.score_candidates(
        params, pkv, np.int32(len(prefix)), jnp.asarray(cands), batch, qcfg))
    ref = _ref_scores(api, params, prefix, cands, batch, qcfg)
    np.testing.assert_allclose(fast, ref, rtol=rtol)

    base_fast = float(api.prefix_qerr(params, pkv, np.int32(len(prefix)),
                                      batch, qcfg))
    single = CC.make_qerr_fn(api, qcfg)
    base_ref = float(single(params, jnp.asarray(prefix, jnp.int32), batch))
    np.testing.assert_allclose(base_fast, base_ref, rtol=rtol)


def test_score_candidates_pt_dynamic_deployment_ranges(tiny):
    """Per-tensor *dynamic* mode: the fast path derives activation ranges
    from the scored sequence only (deployment behaviour — cached prefix
    tokens never re-enter the linears), while the reference recompute folds
    prefix rows into every range. Scores agree to O(1%), not exactly."""
    api, params = tiny
    qcfg = QuantConfig(mode="pt_dynamic")
    batch = _sample(api, 1)
    prefix = [1, 7]
    padded = jnp.asarray(prefix + [0, 0], jnp.int32)
    cands = np.asarray([5, 9, 100, 200], np.int32)
    pkv = api.prefix_kv(params, padded, qcfg)
    fast = np.asarray(api.score_candidates(
        params, pkv, np.int32(2), jnp.asarray(cands), batch, qcfg))
    ref = _ref_scores(api, params, prefix, cands, batch, qcfg)
    assert np.all(np.isfinite(fast))
    np.testing.assert_allclose(fast, ref, rtol=0.1)


def test_score_candidates_vlm(tiny):
    """VLM: the candidate sits between the cushion and the patches; patch
    positions count toward L_q exactly as in the reference scorer."""
    cfg = reduced(get_config("internvl2-26b"), dtype="float32")
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    batch = api.make_batch(jax.random.PRNGKey(5), 1, 24)
    prefix = [1]
    padded = jnp.asarray(prefix + [0, 0], jnp.int32)
    cands = np.asarray([2, 30, 99], np.int32)
    pkv = api.prefix_kv(params, padded, QN)
    fast = np.asarray(api.score_candidates(
        params, pkv, np.int32(1), jnp.asarray(cands), batch, QN))
    ref = _ref_scores(api, params, prefix, cands, batch, QN)
    np.testing.assert_allclose(fast, ref, rtol=1e-4)


def test_score_candidates_moe_contract(tiny):
    """MoE scoring contract: prefix tokens never re-enter the experts in
    the fast path, so the reference's "down"-site L_q exceeds it by a
    candidate-INDEPENDENT offset (prefix expert slots precede and ignore
    the candidate). Ranking — the argmin the search consumes — matches."""
    cfg = reduced(get_config("olmoe-1b-7b"), dtype="float32")
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    batch = api.make_batch(jax.random.PRNGKey(6), 1, 24)
    prefix = [1, 4]
    padded = jnp.asarray(prefix + [0], jnp.int32)
    cands = np.asarray([2, 30, 99, 7], np.int32)
    pkv = api.prefix_kv(params, padded, QP)
    fast = np.asarray(api.score_candidates(
        params, pkv, np.int32(2), jnp.asarray(cands), batch, QP))
    ref = _ref_scores(api, params, prefix, cands, batch, QP)
    diff = ref - fast
    assert np.all(diff > -1e-4)            # reference ≥ fast (extra traffic)
    assert np.std(diff) < 1e-3 * max(np.mean(diff), 1e-9) + 1e-4
    assert int(np.argmin(fast)) == int(np.argmin(ref))


def test_greedy_fast_matches_ref_tokens(tiny_outlier):
    """Acceptance: identical prefix token sequence, fast vs reference, on
    paper_tiny (per-token dynamic quantization)."""
    api, params = tiny_outlier
    ccfg = CushionConfig(max_prefix_len=4, tau=1.5, n_candidates=16,
                         seed_tokens=(1,))
    fast = CC.greedy_search(api, params, lambda i: _sample(api, i), QP, ccfg,
                            jax.random.PRNGKey(0), chunk=8, verbose=False)
    ref = CC.greedy_search_ref(api, params, lambda i: _sample(api, i), QP,
                               ccfg, jax.random.PRNGKey(0), chunk=8,
                               verbose=False)
    np.testing.assert_array_equal(fast.prefix_ids, ref.prefix_ids)
    assert [h["best_tok"] for h in fast.history] == \
        [h["best_tok"] for h in ref.history]


def test_search_compile_count_constant(tiny):
    """The fast search compiles a constant number of executables regardless
    of max_prefix_len (the reference compiles two scorers per appended
    token). A warm-up search populates the process-global jit caches shared
    by both runs (rng helpers, sampling) so the counters see exactly the
    per-search compiles."""
    api, params = tiny

    def run(max_m):
        ccfg = CushionConfig(max_prefix_len=max_m, tau=1.5, n_candidates=8,
                             seed_tokens=(1,))
        return CC.greedy_search(api, params,
                                lambda i: _sample(api, i, n=16), QN, ccfg,
                                jax.random.PRNGKey(0), chunk=8,
                                verbose=False)

    run(2)                                   # warm shared caches
    with count_compiles() as c_short:
        run(3)
    with count_compiles() as c_long:
        run(6)
    assert c_short.count == c_long.count, (c_short.count, c_long.count)
    # and the count is O(1): the fused search step, not per-iteration work
    assert c_long.count <= 4, c_long.count


def test_unsupported_family_falls_back(tiny):
    """ssm/hybrid/encdec: score_candidates refuses (no attention-KV-only
    prefix artifact) and greedy_search transparently delegates to the
    reference implementation."""
    cfg = reduced(get_config("xlstm-350m"), dtype="float32")
    api = build(cfg)
    assert not api.supports_kv_scoring
    params = api.init_params(jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError):
        api.score_candidates(params, None, 0,
                             jnp.asarray([1], jnp.int32),
                             api.make_batch(jax.random.PRNGKey(0), 1, 8), QN)
    ccfg = CushionConfig(max_prefix_len=2, tau=1.5, n_candidates=8,
                         seed_tokens=(1,))
    res = CC.greedy_search(api, params,
                           lambda i: api.make_batch(
                               jax.random.PRNGKey(i), 1, 16),
                           QN, ccfg, jax.random.PRNGKey(0), chunk=8,
                           verbose=False)
    assert 1 <= len(res.prefix_ids) <= 2
    assert res.history
