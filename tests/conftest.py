import os
import sys

# smoke tests and benches must see ONE device (the dry-run launcher sets its
# own 512-device flag before importing jax — never here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
