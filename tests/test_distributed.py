"""Coverage for distributed/collectives.py and distributed/fault_tolerance.py
beyond the substrate smoke tests:

* ``collective_bytes_of_hlo``: the §Roofline collective-term parser — op
  byte/count accounting, async -start/-done forms, dtype widths, tuple
  results skipped;
* ``compressed_psum`` / ``dp_train_step_compressed`` on a REAL multi-device
  mesh (the substrate tests only run the degenerate 1-device reduction):
  int8-payload all-reduce-mean stays within quantization error of the exact
  fp32 mean, and the shard_map'd DP step averages gradients across shards;
* ``Supervisor`` straggler detection and retry exhaustion (the substrate
  tests cover restore-and-replay only).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointManager
from repro.distributed.collectives import (collective_bytes_of_hlo,
                                           compressed_psum,
                                           dp_train_step_compressed)
from repro.distributed.fault_tolerance import Supervisor

NDEV = jax.device_count()

need2 = pytest.mark.skipif(
    NDEV < 2, reason="needs >=2 XLA host devices (run with XLA_FLAGS="
    "--xla_force_host_platform_device_count=8)")


# ---------------------------------------------------------------------------
# HLO collective-bytes parser
# ---------------------------------------------------------------------------

_HLO = """
HloModule test
  %ag = bf16[8,128]{1,0} all-gather(%x), dimensions={0}
  %ar = f32[16]{0} all-reduce-start(%y), to_apply=%add
  %ard = f32[16]{0} all-reduce-done(%ar)
  %rs = s8[64]{0} reduce-scatter(%z), dimensions={0}
  %tup = (f32[4]{0}, f32[4]{0}) tuple(%a, %b)
  %cp = f32[32,2]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
  %scalar = f32[] add(%p, %q)
"""


def test_collective_bytes_of_hlo_accounting():
    out = collective_bytes_of_hlo(_HLO)
    assert out["all-gather"] == 8 * 128 * 2          # bf16
    # -start and -done both match; the parser sums result-shape bytes of
    # every collective *op line* (the double count is deliberate: both ops
    # carry the buffer in the optimized HLO)
    assert out["all-reduce"] == 2 * 16 * 4           # f32, start + done
    assert out["reduce-scatter"] == 64               # s8
    assert out["collective-permute"] == 32 * 2 * 4
    assert out["total"] == sum(out[k] for k in
                               ("all-gather", "all-reduce", "reduce-scatter",
                                "all-to-all", "collective-permute"))
    assert out["counts"]["all-gather"] == 1
    assert out["counts"]["all-reduce"] == 2
    assert out["counts"]["all-to-all"] == 0


def test_collective_bytes_skips_tuples_and_plain_ops():
    out = collective_bytes_of_hlo(
        "%t = (f32[1024]{0}, f32[1024]{0}) all-reduce(%a, %b)\n"
        "%m = f32[1024]{0} multiply(%a, %b)\n")
    assert out["total"] == 0
    assert all(v == 0 for v in out["counts"].values())


# ---------------------------------------------------------------------------
# compressed collectives on a real multi-device mesh
# ---------------------------------------------------------------------------

@need2
def test_compressed_psum_multi_device_mean():
    """int8-payload all-reduce-mean across 2 real shards: every shard sees
    the same result, equal to the fp32 mean within the shared-scale int8
    quantization error bound."""
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.distributed.sharding import shard_map_compat

    rs = np.random.RandomState(3)
    x = jnp.asarray(rs.randn(2, 256).astype(np.float32) * 3.0)
    mesh = Mesh(np.asarray(jax.devices()[:2]), ("data",))
    f = shard_map_compat(lambda v: compressed_psum(v, "data"), mesh,
                         in_specs=P("data"), out_specs=P("data"))
    out = np.asarray(jax.jit(f)(x))
    exact = np.asarray(x).mean(axis=0)
    # each shard holds the mean; scale bound: amax/127 per element, halved
    # by the /2 mean plus rounding
    scale = np.abs(np.asarray(x)).max() / 127.0
    assert np.abs(out[0] - exact).max() <= scale + 1e-6
    np.testing.assert_array_equal(out[0], out[1])


@need2
def test_dp_train_step_compressed_averages_grads():
    """The shard_map'd DP step returns (replicated) loss/grad means that
    match the per-shard fp32 average within int8 comms error."""
    from jax.sharding import Mesh

    def grad_fn(params, batch):
        loss = jnp.mean((batch @ params) ** 2)
        return loss, jax.grad(lambda p: jnp.mean((batch @ p) ** 2))(params)

    mesh = Mesh(np.asarray(jax.devices()[:2]), ("data",))
    fn = dp_train_step_compressed(grad_fn, mesh)
    rs = np.random.RandomState(0)
    params = jnp.asarray(rs.randn(8, 4).astype(np.float32))
    batch = jnp.asarray(rs.randn(4, 8).astype(np.float32))
    loss, grads = fn(params, batch)

    # exact reference: mean of the per-shard losses/grads
    l0, g0 = grad_fn(params, batch[:2])
    l1, g1 = grad_fn(params, batch[2:])
    np.testing.assert_allclose(float(loss), (float(l0) + float(l1)) / 2,
                               rtol=1e-5)
    exact = (np.asarray(g0) + np.asarray(g1)) / 2
    scale = max(np.abs(np.asarray(g0)).max(),
                np.abs(np.asarray(g1)).max()) / 127.0
    assert np.abs(np.asarray(grads) - exact).max() <= scale + 1e-6


# ---------------------------------------------------------------------------
# Supervisor: stragglers and retry exhaustion
# ---------------------------------------------------------------------------

def test_supervisor_flags_stragglers(tmp_path):
    """A step much slower than the rolling median is recorded (the hot-spare
    swap trigger on real pods). The detector needs >= 8 timed steps of
    history before it arms."""
    cm = CheckpointManager(str(tmp_path))
    slow_at = 10

    def do_step(state, step):
        if step == slow_at:
            time.sleep(0.25)
        return {"x": state["x"] + 1}, {"loss": 0.0}

    sup = Supervisor(cm, save_every=100, straggler_factor=3.0)
    _, report = sup.run({"x": jnp.zeros(())}, 0, 14, do_step)
    assert slow_at in report.stragglers
    assert report.failures == 0


def test_supervisor_exhausts_retries(tmp_path):
    """With a checkpoint available, a persistently-failing step is retried
    max_retries times from the restore point and then re-raised."""
    cm = CheckpointManager(str(tmp_path))
    calls = {"fails": 0}

    def do_step(state, step):
        if step == 4:
            calls["fails"] += 1
            raise RuntimeError("hard node failure")
        return {"x": state["x"] + 1}, {"loss": 0.0}

    sup = Supervisor(cm, save_every=2, max_retries=3)
    with pytest.raises(RuntimeError, match="hard node failure"):
        sup.run({"x": jnp.zeros(())}, 0, 8, do_step)
    assert calls["fails"] == sup.max_retries + 1
    assert sup.restores == sup.max_retries


def test_supervisor_retry_budget_is_consecutive(tmp_path):
    """Regression: the retry budget counts *consecutive* failures, not
    lifetime ones. A long run with more total recovered incidents than
    max_retries — each followed by successful steps — must complete; only
    max_retries+1 failures in a row may raise. (The old lifetime counter
    killed week-long runs that had absorbed a handful of spread-out node
    losses.)"""
    cm = CheckpointManager(str(tmp_path))
    failed_at = set()

    def do_step(state, step):
        # 4 transient one-shot failures, spread across the run: each step
        # fails exactly once, succeeds on replay
        if step in (3, 7, 11, 15) and step not in failed_at:
            failed_at.add(step)
            raise RuntimeError("transient node loss")
        return {"x": state["x"] + 1}, {"loss": 0.0}

    sup = Supervisor(cm, save_every=2, max_retries=3,
                     backoff_base_s=0.0)     # keep the test instant
    _, report = sup.run({"x": jnp.zeros(())}, 0, 20, do_step)
    assert len(failed_at) == 4 > sup.max_retries, \
        "trace must exceed the old lifetime budget"
    assert report.completed_steps == 20
    assert report.failures == 4              # lifetime count still reported
    assert sup.health.consecutive_errors == 0


def test_supervisor_backs_off_between_restores(tmp_path, monkeypatch):
    """Restore attempts are separated by capped exponential backoff
    (base * 2**(k-1), k = consecutive failures so far), so a flapping node
    is not hammered with restore/replay cycles."""
    import repro.distributed.fault_tolerance as FT
    cm = CheckpointManager(str(tmp_path))
    sleeps = []
    monkeypatch.setattr(FT.time, "sleep", sleeps.append)
    calls = {"fails": 0}

    def do_step(state, step):
        if step == 4 and calls["fails"] < 3:
            calls["fails"] += 1
            raise RuntimeError("flapping")
        return {"x": state["x"] + 1}, {"loss": 0.0}

    sup = Supervisor(cm, save_every=2, max_retries=3,
                     backoff_base_s=0.1, backoff_cap_s=0.15)
    _, report = sup.run({"x": jnp.zeros(())}, 0, 8, do_step)
    assert report.completed_steps == 8
    # 0.1, 0.2->capped 0.15, 0.4->capped 0.15
    assert sleeps == [pytest.approx(0.1), pytest.approx(0.15),
                      pytest.approx(0.15)]


def test_supervisor_reports_metrics(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    seen = []
    state, report = Supervisor(cm, save_every=100).run(
        {"x": jnp.zeros(())}, 3, 5,
        lambda s, i: ({"x": s["x"] + 1}, {"loss": float(i)}),
        on_metrics=lambda step, m: seen.append((step, m["loss"])))
    assert report.completed_steps == 5
    assert seen == [(i, float(i)) for i in range(3, 8)]
