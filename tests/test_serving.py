"""Continuous-batching serving contract:

* Hypothesis property test for per-row ``pos`` masking in the split-KV
  decode kernel — ragged position vectors (rows at the cushion boundary,
  fully retired rows) match ``flash_decode_ref`` in fp and int8+cushion
  modes, and an all-equal vector reproduces the scalar-pos result exactly;
* per-row pos threading through every family's ``decode_step`` (dense /
  moe / vlm / hybrid): a pool of slots prefilled to different depths
  decodes in one lock-step batch to the same logits as each slot alone;
* the cross-path parity oracle: greedy outputs from ``ContinuousEngine``
  are token-for-token identical to ``Engine.generate`` run per-request,
  including requests admitted mid-flight into a recycled slot, with the
  cushion block bit-identical after recycling (no stale-KV leakage);
* EOS retirement, slot-budget validation, and the documented
  static-Engine-only fallback for families without a slot layout.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import QuantConfig, get_config, reduced
from repro.kernels import ref as R
from repro.kernels.flash_decode import flash_decode
from repro.models.registry import build
from repro.serving import ContinuousEngine, Engine, Request

try:                    # only the property test needs hypothesis; the
    import hypothesis   # scheduler/parity contract must run without it
    import hypothesis.strategies as st
except ImportError:     # pragma: no cover
    hypothesis = st = None

QN = QuantConfig(mode="none")

# ---------------------------------------------------------------------------
# Per-row pos masking property (kernel level)
# ---------------------------------------------------------------------------

_B, _K, _G, _HD, _SMAX, _M = 4, 2, 2, 16, 64, 8
_RS = np.random.RandomState(7)
_Q = jnp.asarray(_RS.randn(_B, _K * _G, _HD).astype(np.float32))
_KF = jnp.asarray(_RS.randn(_B, _SMAX, _K, _HD).astype(np.float32))
_VF = jnp.asarray(_RS.randn(_B, _SMAX, _K, _HD).astype(np.float32))
_KQ = jnp.asarray(_RS.randint(-127, 128, (_B, _SMAX, _K, _HD)), jnp.int8)
_VQ = jnp.asarray(_RS.randint(-127, 128, (_B, _SMAX, _K, _HD)), jnp.int8)
_KS = jnp.asarray(_RS.rand(_K).astype(np.float32) * 0.05 + 0.01)
_VS = jnp.asarray(_RS.rand(_K).astype(np.float32) * 0.05 + 0.01)
_KC = jnp.asarray(_RS.randn(_M, _K, _HD).astype(np.float32))
_VC = jnp.asarray(_RS.randn(_M, _K, _HD).astype(np.float32))


def _check_per_row_pos(pos, quantized):
    posv = jnp.asarray(pos, jnp.int32)
    if quantized:
        out = flash_decode(_Q, _KQ, _VQ, posv, k_scale=_KS, v_scale=_VS,
                           kc=_KC, vc=_VC, bkv=32, interpret=True)
        ref = R.flash_decode_ref(_Q, _KQ, _VQ, posv, k_scale=_KS,
                                 v_scale=_VS, kc=_KC, vc=_VC)
    else:
        out = flash_decode(_Q, _KF, _VF, posv, bkv=32, interpret=True)
        ref = R.flash_decode_ref(_Q, _KF, _VF, posv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("quantized", [False, True], ids=["fp", "int8"])
@pytest.mark.parametrize("pos", [
    [_M, -1, _SMAX - 1, _M - 1],    # cushion boundary, retired, full, m-1
    [-1, -1, -1, 5],                # mostly-retired pool
    [0, 17, 31, 32],                # chunk-edge straddle (bkv=32)
    [3, 60, -1, 33],                # ragged mid-decode pool
])
def test_per_row_pos_masking_cases(pos, quantized):
    """Deterministic per-row pos masking cases (always run, even without
    hypothesis): ragged (B,) position vectors — rows at the cushion
    boundary (pos == m) and fully retired rows (pos == -1) — produce the
    oracle's output row-for-row, fp and int8+cushion."""
    _check_per_row_pos(pos, quantized)


if hypothesis is not None:
    @hypothesis.settings(max_examples=15, deadline=None)
    @hypothesis.example(pos=[_M, -1, _SMAX - 1, _M - 1], quantized=True)
    @hypothesis.example(pos=[-1, -1, -1, 5], quantized=False)
    @hypothesis.example(pos=[0, 17, 31, 32], quantized=False)
    @hypothesis.given(
        pos=st.lists(st.integers(-1, _SMAX - 1), min_size=_B, max_size=_B),
        quantized=st.booleans())
    def test_per_row_pos_masking_property(pos, quantized):
        """Hypothesis-driven version of the masking cases above."""
        _check_per_row_pos(pos, quantized)


def test_uniform_pos_vector_equals_scalar():
    """A (B,) vector with every row equal is bit-identical to the scalar
    path (the static Engine keeps scalar pos; parity must be free)."""
    vec = flash_decode(_Q, _KF, _VF, jnp.full((_B,), 41, jnp.int32),
                       bkv=32, interpret=True)
    sca = flash_decode(_Q, _KF, _VF, 41, bkv=32, interpret=True)
    np.testing.assert_array_equal(np.asarray(vec), np.asarray(sca))


# ---------------------------------------------------------------------------
# Per-slot (B, K) int8 dequant scales (continuous pool calibration)
# ---------------------------------------------------------------------------

_KS_ROW = jnp.asarray(_RS.rand(_B, _K).astype(np.float32) * 0.05 + 0.01)
_VS_ROW = jnp.asarray(_RS.rand(_B, _K).astype(np.float32) * 0.05 + 0.01)


@pytest.mark.parametrize("pos", [[_M, -1, _SMAX - 1, _M - 1],
                                 [3, 60, -1, 33]])
def test_per_row_kv_scales_kernel_matches_ref(pos):
    """(B, K) per-slot dequant scales (each slot calibrated at its own
    admission prefill) route through the kernel's per-row scale index map
    and match the oracle, composed with ragged per-row pos and the fp
    cushion block."""
    posv = jnp.asarray(pos, jnp.int32)
    out = flash_decode(_Q, _KQ, _VQ, posv, k_scale=_KS_ROW, v_scale=_VS_ROW,
                       kc=_KC, vc=_VC, bkv=32, interpret=True)
    ref = R.flash_decode_ref(_Q, _KQ, _VQ, posv, k_scale=_KS_ROW,
                             v_scale=_VS_ROW, kc=_KC, vc=_VC)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_per_row_kv_scales_uniform_equals_shared():
    """Per-row scales with every row equal reproduce the shared-(K,) scale
    result bit-for-bit — the static Engine's layout embeds in the pool's."""
    rows = jnp.broadcast_to(_KS[None], (_B, _K))
    vrows = jnp.broadcast_to(_VS[None], (_B, _K))
    a = flash_decode(_Q, _KQ, _VQ, 41, k_scale=rows, v_scale=vrows,
                     kc=_KC, vc=_VC, bkv=32, interpret=True)
    b = flash_decode(_Q, _KQ, _VQ, 41, k_scale=_KS, v_scale=_VS,
                     kc=_KC, vc=_VC, bkv=32, interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Per-row pos through every family's decode_step
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["smollm-360m", "olmoe-1b-7b",
                                  "internvl2-26b", "jamba-v0.1-52b"])
def test_decode_step_per_row_pos_matches_single_slot(arch):
    """Two slots prefilled to different depths, decoded as one lock-step
    batch with a (B,) pos vector, match each slot decoded alone (B=1,
    scalar pos) — dense, moe, vlm and hybrid (attention KV + Mamba state
    scattered along the family's CACHE_BATCH_AXES)."""
    cfg = reduced(get_config(arch), dtype="float32")
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    axes = api.cache_batch_axes
    max_seq = 64
    rows, poss, toks, ref_logits = [], [], [], []
    for i, L in enumerate((20, 26)):    # make_batch takes total positions
        b = api.make_batch(jax.random.PRNGKey(10 + i), 1, L)
        c = api.init_cache(1, max_seq)
        lg, c, p = api.prefill(params, b, c, QN)
        t = jnp.argmax(lg[:, -1] if lg.ndim == 3 else lg,
                       axis=-1).astype(jnp.int32)
        lr, c1 = api.decode_step(params, t, p, c, QN)   # B=1, scalar pos
        rows.append(c)
        poss.append(p)
        toks.append(t[0])
        ref_logits.append(np.asarray(lr[0]))
    pool = {key: jnp.concatenate([r[key] for r in rows], axis=ax)
            for key, ax in axes.items()}
    lg2, _ = api.decode_step(params, jnp.stack(toks),
                             jnp.stack(poss).astype(jnp.int32), pool, QN)
    for i in range(2):
        np.testing.assert_allclose(np.asarray(lg2[i]), ref_logits[i],
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Cross-path parity oracle: ContinuousEngine vs per-request Engine.generate
# ---------------------------------------------------------------------------

def _family_setup(arch):
    cfg = (get_config(arch) if arch == "paper_tiny"
           else reduced(get_config(arch), dtype="float32"))
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    cushion = api.extract_cushion(params, jnp.asarray([1, 2, 3], jnp.int32),
                                  None, QN)
    return api, params, cushion


@pytest.mark.parametrize("arch", ["paper_tiny", "olmoe-1b-7b",
                                  "internvl2-26b"])
def test_continuous_scheduler_matches_engine(arch):
    """Greedy outputs of the continuous scheduler are token-for-token
    identical to the static Engine run per-request — across requests of
    different prompt lengths and budgets, admitted mid-flight into
    recycled slots, with the cushion prefix block bit-identical after
    recycling."""
    api, params, cushion = _family_setup(arch)
    budgets = [5, 3, 6, 4, 5]
    lens = [20, 26]                     # total positions, two prompt shapes
    reqs = [Request(uid=i, batch=api.make_batch(jax.random.PRNGKey(100 + i),
                                                1, lens[i % 2]),
                    max_new_tokens=n)
            for i, n in enumerate(budgets)]
    ce = ContinuousEngine(api, params, QN, n_slots=2, max_seq=128,
                          cushion=cushion)
    outs = ce.run(reqs)
    assert ce.stats.admitted == len(reqs)
    assert ce.stats.finished == len(reqs)
    assert ce.stats.recycles >= 1, "trace must exercise slot recycling"

    eng = Engine(api, params, QN, cushion=cushion, max_seq=128)
    for req, out in zip(reqs, outs):
        ref = eng.generate(req.batch, req.max_new_tokens).tokens[0]
        np.testing.assert_array_equal(out.tokens, ref)
        assert out.tokens.shape == (req.max_new_tokens,)

    # cushion never evicted, bit-identical in every (recycled) slot
    m = ce.prefix_len
    want = np.asarray(cushion["kv"]["k"]).astype(ce.cache["k"].dtype)
    for s in range(ce.n_slots):
        np.testing.assert_array_equal(np.asarray(ce.cache["k"][:, s, :m]),
                                      want)


@pytest.mark.parametrize("arch", ["paper_tiny", "jamba-v0.1-52b"])
def test_continuous_int8_kv_matches_engine(arch):
    """int8 KV pools serve continuously with per-slot dequant scales: each
    admission's B=1 prefill calibrates its own (layer, head) scales, the
    slot scatter carries them into the pool, and greedy outputs are
    token-for-token identical to the static Engine (whose B=1 int8 prefill
    computes the very same scales) — including recycled slots, whose scale
    rows are overwritten by the incoming request."""
    api, params, cushion = _family_setup(arch)
    budgets = [5, 3, 6, 4]
    reqs = [Request(uid=i, batch=api.make_batch(jax.random.PRNGKey(100 + i),
                                                1, 20),
                    max_new_tokens=n)
            for i, n in enumerate(budgets)]
    ce = ContinuousEngine(api, params, QN, n_slots=2, max_seq=128,
                          cushion=cushion, kv_dtype="int8")
    outs = ce.run(reqs)
    assert ce.stats.recycles >= 1, "trace must exercise slot recycling"
    assert ce.cache["k"].dtype == jnp.int8
    assert ce.cache["k_scale"].shape[1] == ce.n_slots, \
        "int8 pool must hold per-slot scales"

    eng = Engine(api, params, QN, cushion=cushion, max_seq=128,
                 kv_dtype="int8")
    for req, out in zip(reqs, outs):
        ref = eng.generate(req.batch, req.max_new_tokens).tokens[0]
        np.testing.assert_array_equal(out.tokens, ref)

    # protected fp cushion block bit-identical after recycling
    want = cushion["kv"]["k"].astype(ce.cache["kc"].dtype)
    np.testing.assert_array_equal(
        np.asarray(ce.cache["kc"].astype(jnp.float32)),
        np.asarray(want.astype(jnp.float32)))


def test_eos_retires_request_early():
    """A request whose eos_id appears mid-stream retires at the EOS token
    (included in the output) and frees its slot for the queue."""
    api, params, cushion = _family_setup("paper_tiny")
    batch = api.make_batch(jax.random.PRNGKey(5), 1, 12)
    ce = ContinuousEngine(api, params, QN, n_slots=1, max_seq=128,
                          cushion=cushion)
    free = ce.run([Request(uid=0, batch=batch, max_new_tokens=8)])[0]
    # pick an eos whose FIRST occurrence is mid-stream (tiny random models
    # often repeat the very first token)
    j = next((i for i in range(1, len(free.tokens))
              if free.tokens[i] not in free.tokens[:i]), None)
    if j is None:
        pytest.skip("degenerate sample: every generated token identical")
    eos = int(free.tokens[j])
    outs = ce.run([Request(uid=0, batch=batch, max_new_tokens=8, eos_id=eos),
                   Request(uid=1, batch=batch, max_new_tokens=3)])
    np.testing.assert_array_equal(outs[0].tokens, free.tokens[:j + 1])
    assert outs[1].tokens.shape == (3,)
    assert ce.stats.recycles >= 1


def test_budget_validation_and_unsupported_family():
    api, params, cushion = _family_setup("paper_tiny")
    ce = ContinuousEngine(api, params, QN, n_slots=1, max_seq=128,
                          cushion=cushion)
    big = Request(uid=0, batch=api.make_batch(jax.random.PRNGKey(0), 1, 100),
                  max_new_tokens=100)
    # direct admission raises (counted under positions_exhausted)...
    with pytest.raises(ValueError, match="max_seq"):
        ce.try_admit(big)
    assert ce.stats.positions_exhausted == 1
    # ...while run() rejects the over-capacity request explicitly instead
    # of crashing the trace (it can never be served, so it is dropped)
    assert ce.run([big]) == []
    assert ce.stats.positions_exhausted == 1
    assert ce.stats.finished == 0

    # every registry family now publishes a slot layout; the registry-level
    # contract (a module without CACHE_BATCH_AXES -> clear NotImplemented,
    # not a cryptic scatter failure) still holds for out-of-tree modules
    import types

    from repro.models.registry import ModelAPI
    bare = ModelAPI(cfg=api.cfg, mod=types.SimpleNamespace())
    with pytest.raises(NotImplementedError, match="continuous"):
        bare.cache_batch_axes


@pytest.mark.parametrize("arch", ["xlstm-350m", "whisper-base"])
def test_continuous_recurrent_families_match_engine(arch):
    """The families that used to be static-Engine-only serve continuously:
    ssm's state *tree* scatters per-leaf along nested CACHE_BATCH_AXES
    (recurrence ignores per-row pos; dead-row garbage state is overwritten
    by the admission's full-row scatter), and encdec's per-request
    cross-attention KV (xk/xv) rides the slot scatter so slots transcribing
    different audio decode lock-step. Greedy outputs are token-for-token
    identical to the static Engine per-request, through recycled slots."""
    api, params, cushion = _family_setup(arch)
    budgets = [5, 3, 6, 4, 5]
    lens = [20, 26]
    reqs = [Request(uid=i, batch=api.make_batch(jax.random.PRNGKey(100 + i),
                                                1, lens[i % 2]),
                    max_new_tokens=n)
            for i, n in enumerate(budgets)]
    ce = ContinuousEngine(api, params, QN, n_slots=2, max_seq=128,
                          cushion=cushion)
    outs = ce.run(reqs)
    assert ce.stats.admitted == len(reqs)
    assert ce.stats.finished == len(reqs)
    assert ce.stats.recycles >= 1, "trace must exercise slot recycling"

    eng = Engine(api, params, QN, cushion=cushion, max_seq=128)
    for req, out in zip(reqs, outs):
        ref = eng.generate(req.batch, req.max_new_tokens).tokens[0]
        np.testing.assert_array_equal(out.tokens, ref)
        assert out.tokens.shape == (req.max_new_tokens,)


def test_serve_stats_reset_between_runs():
    """Regression: occupancy counters must reset between traces in one
    process (serve_bench warms the scheduler with a full pass before
    measuring — leaked steps/live_slot_steps would corrupt the recorded
    occupancy). Two identical immediate-arrival traces must report
    identical counters, and ``reset()`` zeros everything but n_slots."""
    from repro.monitoring import ServeStats
    s = ServeStats(n_slots=4)
    s.steps, s.live_slot_steps, s.admitted = 10, 33, 7
    s.finished, s.recycles = 6, 2
    s.reset()
    assert s.as_dict() == ServeStats(n_slots=4).as_dict()

    api, params, cushion = _family_setup("paper_tiny")
    reqs = [Request(uid=i, batch=api.make_batch(jax.random.PRNGKey(50 + i),
                                                1, 20), max_new_tokens=4)
            for i in range(3)]
    ce = ContinuousEngine(api, params, QN, n_slots=2, max_seq=128,
                          cushion=cushion)
    ce.run(reqs)
    first = ce.stats.as_dict()
    ce.run(reqs)
    assert ce.stats.as_dict() == first, \
        "second run must not accumulate the first run's counters"
