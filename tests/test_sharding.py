"""Tensor-parallel sharded-serving parity suite.

The serving stack accepts a ``(data, tp)`` mesh (launch/mesh.py
``make_tp_mesh``): params lay out under the TP-only serve rules, the KV
pool shards along its heads axis (models/*.cache_roles), and prefill +
decode run as sharding-constrained jit. This suite pins the contract:

* tp=1 vs tp=2/4 ``Engine.generate`` is token-for-token identical on
  paper_tiny-scale models for dense / moe / vlm / hybrid, fp and int8 KV,
  with prefill and decode logits allclose;
* the fp cushion/sink block is bit-identical on EVERY shard of the sharded
  pool (KVSink/IntactKV: the protected prefix must survive sharding
  exactly — int8 pools keep it replicated in kc/vc, fp pools re-broadcast
  it into rows [0:m) of each shard);
* a hypothesis property test: per-row ``pos`` decode (continuous batching)
  matches the unsharded path for ragged position vectors under the mesh;
* the ``ContinuousEngine`` pool serves sharded with the same outputs;
* the decode loop keeps its compile-once property under the mesh and the
  pool stays device-resident (one jitted scan; the only host syncs are the
  post-prefill token and the final trajectory pull — nothing per-step);
* ``kernels.ops.decode_attention_tp`` (shard_map'd flash-decode with
  per-shard head slicing) matches the oracle in fp and int8+cushion modes.

Multi-device cases skip unless the process sees enough XLA host devices;
CI runs them with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(see the tier-1 matrix), and locally::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest tests/test_sharding.py -q
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import QuantConfig, get_config, reduced
from repro.distributed import sharding as SH
from repro.launch.mesh import make_tp_mesh
from repro.models.registry import build
from repro.serving import ContinuousEngine, Engine, Request

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:     # pragma: no cover
    hypothesis = st = None

QN = QuantConfig(mode="none")
NDEV = jax.device_count()

FAMILY_ARCHS = ("paper_tiny", "olmoe-1b-7b", "internvl2-26b",
                "jamba-v0.1-52b")     # dense / moe / vlm / hybrid


def need_devices(n):
    return pytest.mark.skipif(
        NDEV < n,
        reason=f"needs {n} XLA host devices (run with XLA_FLAGS="
               "--xla_force_host_platform_device_count=8)")


@functools.lru_cache(maxsize=None)
def setup(arch):
    cfg = (get_config(arch) if arch == "paper_tiny"
           else reduced(get_config(arch), dtype="float32"))
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    cushion = api.extract_cushion(params, jnp.asarray([1, 2, 3], jnp.int32),
                                  None, QN)
    return api, params, cushion


@functools.lru_cache(maxsize=None)
def engine(arch, kv, tp):
    """tp=0 -> no mesh (the unsharded reference); tp>=1 -> (1, tp) mesh."""
    api, params, cushion = setup(arch)
    return Engine(api, params, QN, cushion=cushion, max_seq=128,
                  kv_dtype=kv, mesh=make_tp_mesh(tp) if tp else None)


def prefill_logits(eng, batch):
    """Prefill logits + cache under the engine's mesh (Engine only exposes
    the sampled token; the parity contract also wants allclose logits)."""
    B = batch["tokens"].shape[0]
    with SH.use_mesh(eng.mesh):
        cache = eng._init_cache(B)
        logits, cache, pos = eng._prefill(eng.params, batch, cache)
        logits = logits[:, -1] if logits.ndim == 3 else logits
    return logits, cache, pos


# ---------------------------------------------------------------------------
# Token-for-token generation parity + logits allclose
# ---------------------------------------------------------------------------

PARITY_CASES = [(a, kv, 2) for a in FAMILY_ARCHS for kv in (None, "int8")] \
    + [("paper_tiny", kv, 4) for kv in (None, "int8")] \
    + [("olmoe-1b-7b", None, 4)]


@pytest.mark.parametrize("arch,kv,tp", PARITY_CASES,
                         ids=[f"{a}-{kv or 'fp'}-tp{t}"
                              for a, kv, t in PARITY_CASES])
def test_tp_generation_parity(arch, kv, tp):
    """tp=N generation is token-for-token identical to tp=1 and the logits
    agree to fp32-reduction tolerance, for every family and KV precision.
    (vlm/hybrid reduced configs have 2 kv heads: at tp=4 the heads axis
    falls back to replicated per the divisibility contract, so tp=4 runs
    cover the dense/moe 4-kv-head configs.)"""
    if NDEV < tp:
        pytest.skip(f"needs {tp} devices")
    api, _, _ = setup(arch)
    ref = engine(arch, kv, 0)
    tpe = engine(arch, kv, tp)
    batch = api.make_batch(jax.random.PRNGKey(7), 2, 24)

    lg_ref, _, _ = prefill_logits(ref, batch)
    lg_tp, _, _ = prefill_logits(tpe, batch)
    np.testing.assert_allclose(np.asarray(lg_tp), np.asarray(lg_ref),
                               rtol=2e-4, atol=2e-4)

    r = ref.generate(batch, 10)
    o = tpe.generate(batch, 10)
    np.testing.assert_array_equal(o.tokens, r.tokens)


# ---------------------------------------------------------------------------
# Cushion-block bit-identity per shard
# ---------------------------------------------------------------------------

@need_devices(2)
@pytest.mark.parametrize("arch", ["paper_tiny", "jamba-v0.1-52b"])
def test_int8_cushion_block_bit_identical_per_shard(arch):
    """int8 pools keep the protected fp cushion block kc/vc REPLICATED:
    every shard holds the full block, bitwise equal to the searched
    artifact (KVSink/IntactKV under sharding)."""
    api, _, cushion = setup(arch)
    eng = engine(arch, "int8", 2)
    batch = api.make_batch(jax.random.PRNGKey(3), 2, 24)
    _, cache, _ = prefill_logits(eng, batch)
    m = eng.prefix_len
    assert m == 3
    for name, src in (("kc", "k"), ("vc", "v")):
        want = np.asarray(cushion["kv"][src], np.float32)
        shards = cache[name].addressable_shards
        assert len(shards) == eng.mesh.size
        for sh in shards:
            got = np.asarray(sh.data, np.float32)
            assert got.shape == want.shape, "cushion block must be replicated"
            np.testing.assert_array_equal(got, want)


@need_devices(2)
def test_fp_cushion_rows_bit_identical_per_shard():
    """fp pools hold the cushion in-cache at rows [0:m): each shard's local
    slice of those rows equals the corresponding head-slice of the
    artifact, bitwise."""
    api, _, cushion = setup("paper_tiny")
    eng = engine("paper_tiny", None, 2)
    batch = api.make_batch(jax.random.PRNGKey(3), 2, 24)
    _, cache, _ = prefill_logits(eng, batch)
    m = eng.prefix_len
    B = batch["tokens"].shape[0]
    for name in ("k", "v"):
        ck = np.asarray(cushion["kv"][name], np.float32)    # (L, m, K, hd)
        full = np.broadcast_to(ck[:, None], (ck.shape[0], B) + ck.shape[1:])
        assert len(cache[name].addressable_shards) == eng.mesh.size
        for sh in cache[name].addressable_shards:
            got = np.asarray(sh.data)[:, :, :m]
            # shard.index slices the global (L, B, Smax, K, hd); apply the
            # same slices to the broadcast cushion, seq axis := rows [0:m)
            idx = (sh.index[0], sh.index[1], slice(None),
                   sh.index[3], sh.index[4])
            np.testing.assert_array_equal(got, full[idx])


# ---------------------------------------------------------------------------
# Per-row pos decode under sharding (continuous-batching property)
# ---------------------------------------------------------------------------

def _per_row_pos_parity(posv, kv_dtype):
    api, params, _ = setup("paper_tiny")
    cfg = api.cfg
    B, Smax, m = 4, 128, 3
    rng = np.random.RandomState(11)
    cache = api.init_cache(B, Smax, kv_dtype=kv_dtype,
                           prefix_len=m if kv_dtype else 0)
    filled = {}
    for key, leaf in cache.items():
        if leaf.dtype == jnp.int8:
            filled[key] = jnp.asarray(
                rng.randint(-127, 128, leaf.shape), jnp.int8)
        elif key in ("k_scale", "v_scale"):
            filled[key] = jnp.asarray(
                rng.rand(*leaf.shape).astype(np.float32) * 0.05 + 0.01)
        else:
            filled[key] = jnp.asarray(
                rng.randn(*leaf.shape).astype(np.float32) * 0.3)
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (B,)), jnp.int32)
    pos = jnp.asarray(posv, jnp.int32)

    lg_ref, new_ref = jax.jit(
        lambda t, p, c: api.decode_step(params, t, p, c, QN))(
            toks, pos, filled)

    mesh = make_tp_mesh(2)
    sharded = jax.device_put(filled, SH.cache_shardings(
        api.cache_roles(kv_dtype), filled, mesh))
    sp = jax.device_put(params, SH.params_shardings(
        params, mesh, SH.serve_rules()))
    with SH.use_mesh(mesh):
        lg_tp, new_tp = jax.jit(
            lambda t, p, c: api.decode_step(sp, t, p, c, QN))(
                toks, pos, sharded)
    np.testing.assert_allclose(np.asarray(lg_tp), np.asarray(lg_ref),
                               rtol=2e-4, atol=2e-4)
    # the cache write (per-row scatter) lands identically on the shards
    for key in ("k", "v"):
        np.testing.assert_allclose(np.asarray(new_tp[key]),
                                   np.asarray(new_ref[key]),
                                   rtol=2e-4, atol=2e-4)


_POS_CASES = [
    ([3, 40, 127, 5], None),       # ragged mid-decode pool
    ([3, 3, 3, 3], None),          # uniform (static-Engine equivalence)
    ([3, 70, 9, 127], "int8"),     # ragged int8 pool (cushion at [0:3))
]


@need_devices(2)
@pytest.mark.parametrize("posv,kv", _POS_CASES,
                         ids=["fp-ragged", "fp-uniform", "int8-ragged"])
def test_per_row_pos_sharded_cases(posv, kv):
    """Deterministic per-row pos cases (always run, even without
    hypothesis): a lock-step decode over rows at different positions
    produces the same logits and cache writes sharded as unsharded."""
    _per_row_pos_parity(posv, kv)


if hypothesis is not None:
    @need_devices(2)
    @hypothesis.settings(max_examples=8, deadline=None)
    @hypothesis.example(posv=[3, 40, 127, 5], kv_int8=False)
    @hypothesis.example(posv=[3, 70, 9, 127], kv_int8=True)
    @hypothesis.given(
        posv=st.lists(st.integers(3, 127), min_size=4, max_size=4),
        kv_int8=st.booleans())
    def test_per_row_pos_sharded_property(posv, kv_int8):
        """Hypothesis-driven version of the cases above (positions >= m=3:
        the scheduler never decodes below the cushion boundary)."""
        _per_row_pos_parity(posv, "int8" if kv_int8 else None)


# ---------------------------------------------------------------------------
# ContinuousEngine over the mesh
# ---------------------------------------------------------------------------

@need_devices(2)
def test_continuous_engine_tp_parity():
    """The slot-pool scheduler serves sharded with token-for-token the
    outputs of the unsharded pool, the pool resident across devices, and
    the cushion block intact in every recycled slot."""
    api, params, cushion = setup("paper_tiny")
    reqs = [Request(uid=i,
                    batch=api.make_batch(jax.random.PRNGKey(100 + i), 1,
                                         (20, 26)[i % 2]),
                    max_new_tokens=n)
            for i, n in enumerate([5, 3, 6, 4])]
    ref = ContinuousEngine(api, params, QN, n_slots=2, max_seq=128,
                           cushion=cushion).run(reqs)
    ce = ContinuousEngine(api, params, QN, n_slots=2, max_seq=128,
                          cushion=cushion, mesh=make_tp_mesh(2))
    outs = ce.run(reqs)
    for a, b in zip(ref, outs):
        np.testing.assert_array_equal(a.tokens, b.tokens)
    assert ce.stats.recycles >= 1
    assert len(ce.cache["k"].sharding.device_set) == 2
    m = ce.prefix_len
    want = np.asarray(cushion["kv"]["k"], np.float32)
    for s in range(ce.n_slots):
        np.testing.assert_array_equal(
            np.asarray(ce.cache["k"][:, s, :m]), want)


# ---------------------------------------------------------------------------
# Prequantized (int8-resident) serving under the mesh
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _w8a8_setup():
    from repro.core.calibration import calibrate
    api, params, cushion = setup("paper_tiny")
    qw8 = QuantConfig(mode="pt_static", true_int8=True)
    cal = tuple(api.make_batch(jax.random.PRNGKey(100 + i), 2, 32)
                for i in range(2))
    scales, _ = calibrate(api, params, list(cal), qw8, cushion=cushion)
    return api, params, cushion, qw8, scales


@need_devices(2)
def test_tp_prequant_generation_parity():
    """Calibrated pt_static serving with int8-resident weights shards:
    tp=2 generation is token-for-token identical to the unsharded
    prequantized engine AND to the unsharded fp-weight true-int8 path —
    the {w_int, w_scale, colsum} leaves lay out under the serve rules
    (w_int like its fp parent, colsum on the output axis, scales
    replicated) without perturbing a single logit argmax."""
    api, params, cushion, qw8, scales = _w8a8_setup()
    batch = api.make_batch(jax.random.PRNGKey(7), 2, 24)
    ref_fpw = Engine(api, params, qw8, cushion=cushion, scales=scales,
                     max_seq=128)
    ref_pq = Engine(api, params, qw8, cushion=cushion, scales=scales,
                    max_seq=128, prequant=True)
    tp_pq = Engine(api, params, qw8, cushion=cushion, scales=scales,
                   max_seq=128, prequant=True, mesh=make_tp_mesh(2))
    r = ref_pq.generate(batch, 10)
    np.testing.assert_array_equal(r.tokens,
                                  ref_fpw.generate(batch, 10).tokens)
    np.testing.assert_array_equal(tp_pq.generate(batch, 10).tokens,
                                  r.tokens)
    # int8 weights actually sharded: each shard holds half the columns
    w = tp_pq.params["layers"]["attn"]["wqkv"]
    assert w["w_int"].dtype == jnp.int8
    shard = next(iter(w["w_int"].addressable_shards))
    assert shard.data.shape[-1] == w["w_int"].shape[-1] // 2
    cshard = next(iter(w["colsum"].addressable_shards))
    assert cshard.data.shape[-1] == w["colsum"].shape[-1] // 2


@need_devices(2)
def test_tp_continuous_int8_per_slot_scales_parity():
    """The int8 continuous pool (per-slot dequant scales calibrated at each
    admission prefill) serves sharded with the unsharded pool's tokens;
    the per-slot scale leaves shard along heads with batch replicated."""
    api, params, cushion = setup("paper_tiny")
    reqs = [Request(uid=i,
                    batch=api.make_batch(jax.random.PRNGKey(100 + i), 1,
                                         (20, 26)[i % 2]),
                    max_new_tokens=n)
            for i, n in enumerate([5, 3, 6, 4])]
    ref = ContinuousEngine(api, params, QN, n_slots=2, max_seq=128,
                           cushion=cushion, kv_dtype="int8").run(reqs)
    ce = ContinuousEngine(api, params, QN, n_slots=2, max_seq=128,
                          cushion=cushion, kv_dtype="int8",
                          mesh=make_tp_mesh(2))
    outs = ce.run(reqs)
    for a, b in zip(ref, outs):
        np.testing.assert_array_equal(a.tokens, b.tokens)
    assert ce.stats.recycles >= 1
    assert ce.cache["k_scale"].shape == \
        (api.cfg.n_layers, ce.n_slots, api.cfg.n_kv_heads)


def test_prequantized_param_specs_follow_parent_rules():
    """Sharding-rule units for prequantized leaves (single-device: specs
    are computed, not executed): w_int inherits its fp parent's serve
    rules, colsum follows the parent's OUTPUT axis, w_scale replicates."""
    from repro.core import quantization as Q
    from repro.distributed.sharding import params_shardings, serve_rules
    api, params, _ = setup("paper_tiny")
    pq = Q.prequantize_tree(params, QuantConfig(mode="pt_static",
                                                true_int8=True))
    sh = params_shardings(pq, make_tp_mesh(1), serve_rules())
    wqkv = sh["layers"]["attn"]["wqkv"]
    assert wqkv["w_int"].spec == jax.sharding.PartitionSpec(None, None, "tp")
    assert wqkv["colsum"].spec == jax.sharding.PartitionSpec(None, "tp")
    assert wqkv["w_scale"].spec == jax.sharding.PartitionSpec()
    wo = sh["layers"]["attn"]["wo"]
    assert wo["w_int"].spec == jax.sharding.PartitionSpec(None, "tp", None)
    assert wo["colsum"].spec == jax.sharding.PartitionSpec(None, None), \
        "wo's output axis is d_model (unsharded at serve): colsum replicates"


# ---------------------------------------------------------------------------
# Compile-once + device-resident pool under the mesh
# ---------------------------------------------------------------------------

@need_devices(2)
def test_tp_decode_loop_compile_once_and_device_resident():
    """The sharded generation loop keeps PR-1/2's properties: the whole
    decode runs as ONE jitted scan (zero recompiles on a second request of
    the same bucket — so no per-step host round-trip can exist by
    construction), and the KV pool it consumes is a committed multi-device
    array, never pulled to host between steps."""
    from repro.monitoring import count_compiles
    api, _, _ = setup("paper_tiny")
    eng = engine("paper_tiny", None, 2)
    batch = api.make_batch(jax.random.PRNGKey(21), 2, 24)
    eng.generate(batch, 9)      # compile prefill + the 8-step bucket
    tok, pos, cache, _ = eng._run_prefill(batch)
    assert len(cache["k"].sharding.device_set) == 2
    assert len(cache["v"].sharding.device_set) == 2
    with count_compiles() as c:
        out = eng.generate(api.make_batch(jax.random.PRNGKey(22), 2, 24), 9)
    assert c.count == 0, "sharded decode loop must not recompile per request"
    assert out.tokens.shape == (2, 9)


# ---------------------------------------------------------------------------
# shard_map'd flash-decode kernel (per-shard head slicing)
# ---------------------------------------------------------------------------

@need_devices(2)
@pytest.mark.parametrize("quantized", [False, True], ids=["fp", "int8"])
def test_decode_attention_tp_matches_oracle(quantized):
    """kernels.ops.decode_attention_tp — the shard_map'd split-KV kernel
    with local head slices, sharded int8 scales and the replicated cushion
    block sliced per shard — matches flash_decode_ref row-for-row
    (interpret mode; per-row pos with a retired row included)."""
    from repro.kernels import ref as R
    from repro.kernels.ops import decode_attention_tp

    B, K, G, HD, SMAX, M = 2, 4, 2, 16, 64, 8
    rs = np.random.RandomState(5)
    q = jnp.asarray(rs.randn(B, K * G, HD).astype(np.float32))
    pos = jnp.asarray([33, -1], jnp.int32)
    mesh = make_tp_mesh(2)
    if quantized:
        k = jnp.asarray(rs.randint(-127, 128, (B, SMAX, K, HD)), jnp.int8)
        v = jnp.asarray(rs.randint(-127, 128, (B, SMAX, K, HD)), jnp.int8)
        ks = jnp.asarray(rs.rand(K).astype(np.float32) * 0.05 + 0.01)
        vs = jnp.asarray(rs.rand(K).astype(np.float32) * 0.05 + 0.01)
        kc = jnp.asarray(rs.randn(M, K, HD).astype(np.float32))
        vc = jnp.asarray(rs.randn(M, K, HD).astype(np.float32))
        out = decode_attention_tp(q, k, v, pos, mesh, k_scale=ks, v_scale=vs,
                                  kc=kc, vc=vc, interpret=True)
        ref = R.flash_decode_ref(q, k, v, pos, k_scale=ks, v_scale=vs,
                                 kc=kc, vc=vc)
    else:
        k = jnp.asarray(rs.randn(B, SMAX, K, HD).astype(np.float32))
        v = jnp.asarray(rs.randn(B, SMAX, K, HD).astype(np.float32))
        out = decode_attention_tp(q, k, v, pos, mesh, interpret=True)
        ref = R.flash_decode_ref(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@need_devices(2)
@pytest.mark.parametrize("kv", [None, "int8"], ids=["fp", "int8"])
def test_model_decode_routes_through_tp_kernel(monkeypatch, kv):
    """Model-level routing: with the Pallas kernel forced (interpret mode)
    under a tp mesh, ``attention_decode_kv`` takes the shard_map'd
    per-shard-heads path (paper_tiny: 4 kv heads % tp=2 == 0) and produces
    the jnp fallback's logits."""
    import repro.flags as F
    api, params, cushion = setup("paper_tiny")
    eng = Engine(api, params, QN, cushion=cushion, max_seq=128,
                 kv_dtype=kv, mesh=make_tp_mesh(2))
    batch = api.make_batch(jax.random.PRNGKey(13), 2, 24)
    tok, pos, cache, _ = eng._run_prefill(batch)
    with SH.use_mesh(eng.mesh):
        lg_jnp, _ = jax.jit(lambda t, p, c: api.decode_step(
            eng.params, t, p, c, QN))(tok, pos, cache)
        monkeypatch.setattr(F, "DECODE_KERNEL", "pallas")
        lg_tp, _ = jax.jit(lambda t, p, c: api.decode_step(
            eng.params, t, p, c, QN))(tok, pos, cache)
    np.testing.assert_allclose(np.asarray(lg_tp), np.asarray(lg_jnp),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Single-device contract pieces (always run in plain tier-1)
# ---------------------------------------------------------------------------

def test_trivial_tp1_mesh_matches_no_mesh():
    """A (1, 1) tp mesh exercises the whole sharded code path (device_put
    with NamedShardings, use_mesh tracing, cache_shardings) and must be a
    bit-exact no-op vs the mesh-free engine."""
    api, params, cushion = setup("paper_tiny")
    batch = api.make_batch(jax.random.PRNGKey(9), 2, 24)
    ref = engine("paper_tiny", None, 0).generate(batch, 8)
    out = engine("paper_tiny", None, 1).generate(batch, 8)
    np.testing.assert_array_equal(out.tokens, ref.tokens)


def test_tp_role_resolution_and_cache_shardings():
    """"M" resolves to the tp axis on serving meshes and to model on
    training meshes; cache_shardings lays every pool leaf out per the
    family template with indivisible axes dropped to replicated."""
    from jax.sharding import Mesh, PartitionSpec as P
    tp_mesh = make_tp_mesh(1)
    assert SH.to_pspec(("M",), tp_mesh) == P("tp")
    assert SH.to_pspec(("B",), tp_mesh) == P("data")
    train_mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                      ("data", "model"))
    assert SH.to_pspec(("M",), train_mesh) == P("model")

    api, _, _ = setup("paper_tiny")
    cache = api.init_cache(2, 128, kv_dtype="int8", prefix_len=3)
    sh = SH.cache_shardings(api.cache_roles("int8"), cache, tp_mesh)
    assert set(sh) == set(cache)
    assert sh["k"].spec == P(None, "data", None, "tp", None)
    # cushion block replicated (no mesh axis anywhere in its spec)
    assert not any(ax is not None for ax in sh["kc"].spec)
    assert sh["k_scale"].spec == P(None, "tp")

    # indivisible dims fall back to replicated instead of GSPMD padding
    assert SH.roles_pspec(("M",), (7,), tp_mesh) == P("tp")   # 7 % 1 == 0
    assert SH.roles_pspec((None, "M"), (4, 6), tp_mesh) == P(None, "tp")


@need_devices(2)
def test_roles_pspec_drops_indivisible_axes():
    api, _, _ = setup("paper_tiny")
    from jax.sharding import PartitionSpec as P
    mesh = make_tp_mesh(2)
    assert SH.roles_pspec(("M",), (8,), mesh) == P("tp")
    assert SH.roles_pspec(("M",), (7,), mesh) == P(None)
    # vlm/hybrid smoke configs: 2 kv heads over tp=2 shard; over tp=4 they
    # would be dropped (covered implicitly by the tp=4 parity cases)
    assert SH.roles_pspec((None, "B", None, "M"), (4, 2, 64, 2), mesh) \
        == P(None, "data", None, "tp")


def test_cache_roles_uniform_across_families():
    """Every family answers ModelAPI.cache_roles (uniform kv_dtype kwarg —
    regression: xlstm/encdec used to TypeError), and cache_shardings lays
    out nested state trees (xlstm) and untemplated leaves without error."""
    from jax.sharding import NamedSharding
    mesh = make_tp_mesh(1)
    for arch in ("xlstm-350m", "whisper-base", "jamba-v0.1-52b"):
        api = build(reduced(get_config(arch), dtype="float32"))
        roles = api.cache_roles()
        assert isinstance(roles, dict) and roles
        cache = jax.eval_shape(lambda a=api: a.init_cache(2, 64))
        sh = SH.cache_shardings(roles, cache, mesh)
        flat_c = jax.tree_util.tree_leaves(cache)
        flat_s = jax.tree_util.tree_leaves(
            sh, is_leaf=lambda x: isinstance(x, NamedSharding))
        assert len(flat_s) == len(flat_c)
        assert all(isinstance(s, NamedSharding) for s in flat_s)
    # roles template missing entries entirely -> everything replicated
    sh = SH.cache_shardings({}, {"a": jax.ShapeDtypeStruct((4, 4), jnp.float32)},
                            mesh)
    assert not any(ax is not None for ax in sh["a"].spec)


def test_make_tp_mesh_validates_device_count():
    with pytest.raises(RuntimeError, match="xla_force_host_platform"):
        make_tp_mesh(NDEV + 1)
