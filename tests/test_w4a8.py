"""W4A8 (int4-packed weights, int8 activations) serving-path contract:

* pack/unpack round-trip — ``pack_int4``/``unpack_int4`` are exact inverses
  over the full signed nibble range [-8, 7] for even AND odd K (the odd
  tail nibble is zero-padded and sliced back off), pinned by parametrized
  cases and a hypothesis property when hypothesis is installed;
* three-way matmul parity — the Pallas unpack-in-VMEM kernel
  (``w4a8_matmul``, interpret mode off-TPU), the jnp fallback inside
  ``prequantized_int_dot`` and the pure-jnp oracle (``w4a8_matmul_ref``)
  agree on ragged token counts, group boundaries and asymmetric activation
  zero-points. Tolerance is rtol=1e-4/atol=1e-3 — looser than W8A8's
  because the three routes order the group-scale f32 accumulation
  differently (per-group subtract, folded-scale single GEMM, per-block
  scaled accumulate) and only agree to f32 rounding, not bit-identically;
* the ``REPRO_W4A8_KERNEL`` routing flag, outside and inside jit (decode
  scans trace qdot under jit, so routing must hold there);
* ``prequantize(weight_bits=4)`` format — packed shape ceil(K/2),
  group-wise scales, scaled colsum — including odd-K and
  group-indivisible fallbacks, plus ``prequantize_tree`` over stacked
  (scan-layer) leaves;
* engine-level: int4-resident generation is token-identical across kernel
  routes, ``weight_bytes_int4`` accounting is exactly half the int8
  residency, and the weight_bits guards refuse unsupported widths and
  non-prequantized int4.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.flags as flags
from repro.configs import QuantConfig, get_config
from repro.core import quantization as Q
from repro.kernels import ref as R
from repro.kernels.w4a8_matmul import w4a8_matmul
from repro.models.registry import build
from repro.serving import ContinuousEngine, Engine

try:
    import hypothesis
    import hypothesis.extra.numpy as hnp
    import hypothesis.strategies as st
except ImportError:     # pragma: no cover
    hypothesis = hnp = st = None

QW8 = QuantConfig(mode="pt_static", true_int8=True)


def _site_for(x):
    scale, zero = Q.params_from_minmax(jnp.min(x), jnp.max(x), 8, False)
    return Q.SiteScale(scale=scale, zero=zero)


# ---------------------------------------------------------------------------
# pack/unpack round-trip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("K", [1, 2, 7, 8, 33, 256])
def test_pack_unpack_roundtrip(K):
    """Exact inverse over the full signed nibble range, even and odd K."""
    rng = np.random.RandomState(K)
    wq = jnp.asarray(rng.randint(-8, 8, (K, 24)), jnp.int8)
    packed = Q.pack_int4(wq)
    assert packed.shape == ((K + 1) // 2, 24) and packed.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(Q.unpack_int4(packed, K)),
                                  np.asarray(wq))


def test_pack_unpack_extreme_nibbles():
    """-8 (0b1000: sign-extension pivot) and 7 survive both nibble slots."""
    wq = jnp.asarray([[-8, 7], [7, -8], [-8, -8], [7, 7], [-1, 0]],
                     jnp.int8)
    np.testing.assert_array_equal(
        np.asarray(Q.unpack_int4(Q.pack_int4(wq), 5)), np.asarray(wq))


if hypothesis is not None:
    @hypothesis.settings(max_examples=25, deadline=None)
    @hypothesis.given(
        st.integers(min_value=1, max_value=70),
        st.integers(min_value=1, max_value=16),
        st.integers(min_value=0, max_value=2 ** 31 - 1))
    def test_pack_unpack_roundtrip_property(k, n, seed):
        """ANY (K, N) in-range int4 matrix round-trips exactly — odd K,
        K straddling pack-pair and group boundaries, extreme nibbles."""
        rng = np.random.RandomState(seed)
        wq = jnp.asarray(rng.randint(-8, 8, (k, n)), jnp.int8)
        np.testing.assert_array_equal(
            np.asarray(Q.unpack_int4(Q.pack_int4(wq), k)), np.asarray(wq))


# ---------------------------------------------------------------------------
# Kernel-level three-way parity
# ---------------------------------------------------------------------------

def _packed_case(rng, M, K, N, group):
    x = jnp.asarray(rng.randint(-128, 128, (M, K)), jnp.int8)
    wq = jnp.asarray(rng.randint(-7, 8, (K, N)), jnp.int8)
    s_w = jnp.asarray(rng.rand(K // group, N).astype(np.float32) * 0.02
                      + 1e-3)
    colsum_g = jnp.sum(wq.astype(jnp.int32).reshape(K // group, group, N),
                       axis=1)
    colsum = jnp.sum(colsum_g.astype(jnp.float32) * s_w, axis=0)
    return x, Q.pack_int4(wq), s_w, colsum


@pytest.mark.parametrize("M", [37, 128, 300])
@pytest.mark.parametrize("group", [64, 256])
def test_w4a8_kernel_ref_parity_ragged(M, group):
    """Pallas kernel == jnp oracle on ragged M with an asymmetric activation
    zero-point, for a multi-group and a single-group contraction."""
    rng = np.random.RandomState(M + group)
    K, N = 256, 128
    x, packed, s_w, colsum = _packed_case(rng, M, K, N, group)
    s_x, z_x = 0.013, -3.0
    ref = R.w4a8_matmul_ref(x, packed, jnp.float32(s_x), jnp.float32(z_x),
                            s_w, group_size=group)
    out = w4a8_matmul(x, packed, s_x, z_x, s_w, colsum, group_size=group,
                      interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-3)


def test_w4a8_three_way_parity_serving_dot():
    """qdot on an int4-prequantized weight (jnp route AND forced-Pallas
    route) matches the oracle fed the same packed tensor — the serving dot,
    the kernel and the reference agree on what the format means."""
    rng = np.random.RandomState(0)
    M, K, N = 50, 256, 128
    x = jnp.asarray(rng.randn(M, K).astype(np.float32) * 2 + 0.7)
    w = jnp.asarray(rng.randn(K, N).astype(np.float32) * 0.1)
    site = _site_for(x)
    assert float(site.zero) != 0.0, "case must exercise the zero-point"
    cfg = QW8
    pq = Q.prequantize(w, cfg, weight_bits=4)
    group = K // pq["w_scale"].shape[0]

    # oracle on the exact serving quantization of x (int8 offset by -128)
    xq = (Q.quantize(x, site.scale, site.zero, 8, False) - 128)
    ref = R.w4a8_matmul_ref(xq.astype(jnp.int8), pq["w_packed"],
                            jnp.asarray(site.scale, jnp.float32),
                            jnp.asarray(site.zero - 128.0, jnp.float32),
                            pq["w_scale"], group_size=group)
    for route in ("jnp", "pallas"):
        old = flags.W4A8_KERNEL
        flags.W4A8_KERNEL = route
        try:
            out = Q.qdot(x, pq, cfg, site)
        finally:
            flags.W4A8_KERNEL = old
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-3, err_msg=route)


def test_w4a8_kernel_routing_flag(monkeypatch):
    """REPRO_W4A8_KERNEL=pallas routes the int4 serving dot through the
    Pallas kernel (interpret off-TPU) with the same numbers as the jnp
    fallback — outside AND inside jit."""
    rng = np.random.RandomState(5)
    x = jnp.asarray(rng.randn(3, 19, 256).astype(np.float32))
    w = jnp.asarray(rng.randn(256, 128).astype(np.float32) * 0.1)
    site = _site_for(x)
    pq = Q.prequantize(w, QW8, weight_bits=4)

    monkeypatch.setattr(flags, "W4A8_KERNEL", "jnp")
    ref = Q.qdot(x, pq, QW8, site)
    monkeypatch.setattr(flags, "W4A8_KERNEL", "pallas")
    out = Q.qdot(x, pq, QW8, site)
    jit_out = jax.jit(lambda x: Q.qdot(x, pq, QW8, site))(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(jit_out), np.asarray(ref),
                               rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# prequantize format
# ---------------------------------------------------------------------------

def test_prequantize_int4_format():
    rng = np.random.RandomState(1)
    w = jnp.asarray(rng.randn(256, 96).astype(np.float32))
    pq = Q.prequantize(w, QW8, weight_bits=4)
    G = 256 // QW8.w_group
    assert pq["w_packed"].shape == (128, 96)
    assert pq["w_packed"].dtype == jnp.int8
    assert pq["w_scale"].shape == (G, 96)
    assert pq["colsum"].shape == (96,)
    # colsum carries the group scales: equals sum_k s_w[g(k)] * wq[k]
    wq = Q.unpack_int4(pq["w_packed"], 256).astype(jnp.float32)
    s_full = jnp.repeat(pq["w_scale"], QW8.w_group, axis=0)
    np.testing.assert_allclose(np.asarray(pq["colsum"]),
                               np.asarray(jnp.sum(wq * s_full, axis=0)),
                               rtol=1e-5, atol=1e-4)


def test_prequantize_int4_odd_and_indivisible_K():
    """K that the configured group doesn't divide falls back to one
    per-column group; odd K packs ceil(K/2) byte rows."""
    rng = np.random.RandomState(2)
    w = jnp.asarray(rng.randn(33, 16).astype(np.float32))
    pq = Q.prequantize(w, QW8, weight_bits=4)
    assert pq["w_packed"].shape == (17, 16)
    assert pq["w_scale"].shape == (1, 16)
    x = jnp.asarray(rng.randn(4, 33).astype(np.float32))
    site = _site_for(x)
    ref = Q.qdot(x, w, QuantConfig(mode="pt_static", true_int8=False,
                                   w_bits=4), site)
    out = Q.qdot(x, pq, QW8, site)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-3)


def test_prequantize_tree_int4_stacked_leaves():
    """Scan-stacked (L, K, N) leaves prequantize per layer slice; packed
    dicts replace exactly the leaves the int8 tree converts."""
    cfg = get_config("paper_tiny")
    api = build(cfg)
    p = api.init_params(jax.random.PRNGKey(0))
    p8 = Q.prequantize_tree(p, QW8)
    p4 = Q.prequantize_tree(p, QW8, weight_bits=4)
    flat8 = {k: v for k, v in jax.tree_util.tree_flatten_with_path(p8)[0]}
    flat4 = {k: v for k, v in jax.tree_util.tree_flatten_with_path(p4)[0]}
    packed = [k for k in flat4 if "w_packed" in str(k[-1])]
    assert packed, "no packed leaves produced"
    assert len(packed) == len(
        [k for k in flat8 if "w_int" in str(k[-1])])
    for kp in packed:
        k8 = kp[:-1] + (jax.tree_util.DictKey("w_int"),)
        assert flat4[kp].dtype == jnp.int8
        # packed K/2 rows on the stacked leaf's contracting axis
        assert flat4[kp].shape[-2] == -(-flat8[k8].shape[-2] // 2)
    with pytest.raises(ValueError, match="weight_bits"):
        Q.prequantize_tree(p, QW8, weight_bits=3)


# ---------------------------------------------------------------------------
# Engine-level: generation parity, residency accounting, guards
# ---------------------------------------------------------------------------

def _setup():
    cfg = get_config("paper_tiny")
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    cal = [api.make_batch(jax.random.PRNGKey(100 + i), 2, 32)
           for i in range(2)]
    batch = api.make_batch(jax.random.PRNGKey(7), 2, 24)
    return api, params, cal, batch


def test_w4a8_engine_route_parity_and_bytes(monkeypatch):
    """int4-resident generation is token-identical between the jnp fallback
    and the forced-Pallas route, and the packed residency is exactly half
    the int8 residency (2 nibbles/byte over the same weight set)."""
    api, params, cal, batch = _setup()
    e8 = Engine(api, params, QW8, max_seq=96, calib_batches=cal,
                prequant=True)
    monkeypatch.setattr(flags, "W4A8_KERNEL", "jnp")
    e4j = Engine(api, params, QW8, max_seq=96, calib_batches=cal,
                 prequant=True, weight_bits=4)
    r_jnp = e4j.generate(batch, 8)
    monkeypatch.setattr(flags, "W4A8_KERNEL", "pallas")
    e4p = Engine(api, params, QW8, max_seq=96, calib_batches=cal,
                 prequant=True, weight_bits=4)
    r_pal = e4p.generate(batch, 8)
    np.testing.assert_array_equal(r_pal.tokens, r_jnp.tokens)
    assert e4j.weight_bytes_int4 == e8.weight_bytes_int8 // 2
    assert e4j.weight_bytes_int8 == 0 and e8.weight_bytes_int4 == 0


def test_w4a8_continuous_engine_matches_static(monkeypatch):
    """ContinuousEngine(weight_bits=4) serves the same packed tree as the
    static Engine: greedy tokens agree request-for-request."""
    monkeypatch.setattr(flags, "W4A8_KERNEL", "jnp")
    api, params, cal, batch = _setup()
    eng = Engine(api, params, QW8, max_seq=96, calib_batches=cal,
                 prequant=True, weight_bits=4)
    want = eng.generate(batch, 8).tokens
    ce = ContinuousEngine(api, params, QW8, n_slots=2, max_seq=96,
                          calib_batches=cal, prequant=True, weight_bits=4)
    assert ce.stats.weight_bytes_int4 > 0
    from repro.serving.scheduler import Request
    outs = ce.run([Request(uid=i, batch={"tokens": batch["tokens"][i:i + 1]},
                           max_new_tokens=8) for i in range(2)])
    got = np.stack([o.tokens for o in sorted(outs, key=lambda o: o.uid)])
    np.testing.assert_array_equal(got, want)


def test_weight_bits_guards():
    api, params, cal, _ = _setup()
    with pytest.raises(ValueError, match="weight_bits"):
        Engine(api, params, QW8, max_seq=96, calib_batches=cal,
               prequant=True, weight_bits=3)
    with pytest.raises(ValueError, match="prequant"):
        Engine(api, params, QW8, max_seq=96, calib_batches=cal,
               weight_bits=4)
    rng = np.random.RandomState(0)
    with pytest.raises(ValueError, match="weight_bits"):
        Q.prequantize(jnp.asarray(rng.randn(16, 8), jnp.float32), QW8,
                      weight_bits=5)
