"""Calibrated W8A8 serving-path contract:

* three-way matmul parity — the Pallas kernel pipeline (``qdot_pallas``),
  the int8-resident serving dot (``prequantized_int_dot``) and the pure-jnp
  oracle (``w8a8_matmul_ref``) agree on ragged token counts and asymmetric
  activation zero-points;
* the ``REPRO_W8A8_KERNEL`` routing flag: Pallas-forced (interpret-mode)
  execution of ``true_int_dot``/``prequantized_int_dot`` matches the
  lax.dot_general path, including under jit (the decode-scan context);
* ``prequantize_tree`` converts exactly the qdot-consumed weights across
  families (hybrid's list-nested period params included; MoE experts and
  embeddings stay fp);
* the engines' load-time quantization plan: pt_static with neither scales
  nor calibration data refuses to run (the placeholder-scales silent-garbage
  guard), engine-side calibration equals precomputed-scales serving, and
  prequantized (int8-resident) generation is token-for-token identical to
  the fp-weight true-int8 path for dense / moe / vlm / hybrid;
* ``monitoring.resident_weight_bytes`` accounting for the fp-vs-int8 A/B.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.flags as flags
from repro.configs import QuantConfig, get_config, reduced
from repro.core import quantization as Q
from repro.kernels import ref as R
from repro.kernels.ops import qdot_pallas
from repro.kernels.w8a8_matmul import w8a8_matmul
from repro.models.registry import build
from repro.serving import ContinuousEngine, Engine

QW8 = QuantConfig(mode="pt_static", true_int8=True)


def _site_for(x):
    scale, zero = Q.params_from_minmax(jnp.min(x), jnp.max(x), 8, False)
    return Q.SiteScale(scale=scale, zero=zero)


# ---------------------------------------------------------------------------
# Kernel-level parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M", [37, 128, 300])
def test_qdot_pallas_prequantized_ref_three_way_parity(M):
    """qdot_pallas == prequantized_int_dot == w8a8_matmul_ref on ragged M
    with an asymmetric activation zero-point (the deployment configuration:
    asymmetric per-tensor-static activations, symmetric per-tensor
    weights)."""
    rng = np.random.RandomState(M)
    x = jnp.asarray(rng.randn(M, 256).astype(np.float32) * 2 + 0.7)
    w = jnp.asarray(rng.randn(256, 128).astype(np.float32) * 0.1)
    site = _site_for(x)
    assert float(site.zero) != 0.0, "case must exercise the zero-point"

    a = qdot_pallas(x, w, QW8, site)                    # Pallas pipeline
    pq = Q.prequantize(w, QW8)
    b = Q.qdot(x, pq, QW8, site)                        # int8-resident dot

    # oracle: quantize activations exactly as the serving path stores them
    # (int8 offset by -128), then the ref matmul with the shifted zero
    xq = Q.quantize(x, site.scale, site.zero, 8, False) - 128
    wq, s_w = Q.weight_quant_int(w, QW8)
    c = R.w8a8_matmul_ref(xq.astype(jnp.int8), wq,
                          jnp.asarray(site.scale, jnp.float32),
                          jnp.asarray(site.zero - 128.0, jnp.float32),
                          jnp.asarray(s_w, jnp.float32))
    np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(b), np.asarray(c),
                               rtol=1e-5, atol=1e-4)


def test_w8a8_matmul_precomputed_colsum_identical():
    """The stored-colsum fast path (prequantized serving) is bit-identical
    to the kernel's own reduction."""
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randint(-127, 128, (64, 256)), jnp.int8)
    w = jnp.asarray(rng.randint(-127, 128, (256, 128)), jnp.int8)
    colsum = jnp.sum(w.astype(jnp.int32), axis=0)
    a = w8a8_matmul(x, w, 0.01, -3.0, 0.02, interpret=True)
    b = w8a8_matmul(x, w, 0.01, -3.0, 0.02, colsum=colsum, interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("prequantized", [False, True],
                         ids=["true_int_dot", "prequantized"])
def test_w8a8_kernel_routing_flag(monkeypatch, prequantized):
    """REPRO_W8A8_KERNEL=pallas routes the serving int8 dots through the
    Pallas kernel (interpret mode off-TPU) with the same numbers as the
    lax.dot_general path — outside AND inside jit (the decode scan traces
    qdot under jit, so the routing must hold there too)."""
    rng = np.random.RandomState(5)
    x = jnp.asarray(rng.randn(3, 19, 256).astype(np.float32))
    w = jnp.asarray(rng.randn(256, 128).astype(np.float32) * 0.1)
    site = _site_for(x)
    warg = Q.prequantize(w, QW8) if prequantized else w

    monkeypatch.setattr(flags, "W8A8_KERNEL", "jnp")
    ref = Q.qdot(x, warg, QW8, site)
    monkeypatch.setattr(flags, "W8A8_KERNEL", "pallas")
    out = Q.qdot(x, warg, QW8, site)
    jit_out = jax.jit(lambda x: Q.qdot(x, warg, QW8, site))(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(jit_out), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# prequantize_tree coverage
# ---------------------------------------------------------------------------

def test_prequantize_tree_hybrid_descends_period_lists():
    """Hybrid period params nest sublayers in lists: attention / mamba /
    dense-mlp weights inside them convert to int8-resident dicts; MoE
    sublayers (expert einsums + Arctic residual) and embeddings stay fp."""
    cfg = reduced(get_config("jamba-v0.1-52b"), dtype="float32")
    api = build(cfg)
    p = api.init_params(jax.random.PRNGKey(0))
    pq = Q.prequantize_tree(p, QW8)
    subs = pq["layers"]["sub"]
    kinds = {}
    for sub in subs:
        for mixer in ("attn", "mamba", "mlp", "moe"):
            if mixer in sub:
                kinds[mixer] = sub[mixer]
    assert pq["layers"]["sub"] is not p["layers"]["sub"]
    assert "w_int" in kinds["attn"]["wqkv"]
    assert kinds["attn"]["wqkv"]["w_int"].dtype == jnp.int8
    assert "w_int" in kinds["mamba"]["w_in"]
    assert not isinstance(kinds["mamba"]["w_x"], dict)   # raw einsum: fp
    assert "w_int" in kinds["mlp"]["w_down"]
    assert not isinstance(kinds["moe"]["w_up"], dict)    # experts: fp
    assert not isinstance(pq["embed"]["w"], dict)
    # stacked-over-periods leaves quantize per period slice
    P = kinds["attn"]["wqkv"]["w_int"].shape[0]
    assert kinds["attn"]["wqkv"]["w_scale"].shape == (P,)
    assert kinds["attn"]["wqkv"]["colsum"].shape == \
        (P, kinds["attn"]["wqkv"]["w_int"].shape[-1])


# ---------------------------------------------------------------------------
# Placeholder-scales guard (silent-garbage prevention)
# ---------------------------------------------------------------------------

def test_pt_static_forward_without_scales_raises():
    cfg = get_config("paper_tiny")
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    batch = api.make_batch(jax.random.PRNGKey(1), 2, 16)
    with pytest.raises(ValueError, match="calibrated scales"):
        api.forward(params, batch, QuantConfig(mode="pt_static"))
    # dynamic modes still run on placeholders (values unused)
    api.forward(params, batch, QuantConfig(mode="pt_dynamic"))


def test_pt_static_engines_without_scales_raise():
    cfg = get_config("paper_tiny")
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="calib"):
        Engine(api, params, QW8, max_seq=128)
    with pytest.raises(ValueError, match="calib"):
        ContinuousEngine(api, params, QW8, n_slots=1, max_seq=128)
    with pytest.raises(ValueError, match="pt_static"):
        Engine(api, params, QuantConfig(mode="none"), max_seq=128,
               prequant=True)


def test_prequantized_int_dot_requires_static_site():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(4, 64).astype(np.float32))
    w = Q.prequantize(jnp.asarray(rng.randn(64, 32).astype(np.float32)), QW8)
    with pytest.raises(ValueError, match="pt_static"):
        Q.prequantized_int_dot(x, w, QuantConfig(mode="pt_dynamic"), None)
    with pytest.raises(ValueError, match="site"):
        Q.prequantized_int_dot(x, w, QW8, None)


# ---------------------------------------------------------------------------
# End-to-end: load-time plan + generation parity across families
# ---------------------------------------------------------------------------

def _arch_setup(arch):
    cfg = (get_config(arch) if arch == "paper_tiny"
           else reduced(get_config(arch), dtype="float32"))
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    cal = [api.make_batch(jax.random.PRNGKey(100 + i), 2, 32)
           for i in range(2)]
    batch = api.make_batch(jax.random.PRNGKey(7), 2, 24)
    return api, params, cal, batch


@pytest.mark.parametrize("arch", ["paper_tiny", "olmoe-1b-7b",
                                  "internvl2-26b", "jamba-v0.1-52b"])
def test_prequant_generation_parity(arch):
    """int8-resident (prequantized) serving generates token-for-token what
    the fp-weight true-int8 pt_static path generates — same int math, only
    the weight residency differs — for dense / moe / vlm / hybrid, with the
    engine calibrating its own scales at load."""
    api, params, cal, batch = _arch_setup(arch)
    e_fpw = Engine(api, params, QW8, max_seq=128, calib_batches=cal)
    e_pq = Engine(api, params, QW8, max_seq=128, calib_batches=cal,
                  prequant=True)
    r_fpw = e_fpw.generate(batch, 8)
    r_pq = e_pq.generate(batch, 8)
    np.testing.assert_array_equal(r_pq.tokens, r_fpw.tokens)
    assert e_pq.weight_bytes_int8 > 0
    assert e_fpw.weight_bytes_int8 == 0
    # int8 residency strictly shrinks the fp footprint it replaces
    assert e_pq.weight_bytes_fp < e_fpw.weight_bytes_fp


def test_engine_load_time_calibration_matches_precomputed():
    """Engine(calib_batches=...) reproduces Engine(scales=calibrate(...))
    exactly — the load-time plan is the same calibration, just owned by
    the engine."""
    from repro.core.calibration import calibrate
    api, params, cal, batch = _arch_setup("paper_tiny")
    scales, _ = calibrate(api, params, cal, QW8)
    r_pre = Engine(api, params, QW8, max_seq=128,
                   scales=scales).generate(batch, 8)
    r_load = Engine(api, params, QW8, max_seq=128,
                    calib_batches=cal).generate(batch, 8)
    np.testing.assert_array_equal(r_load.tokens, r_pre.tokens)


def test_resident_weight_bytes_accounting():
    from repro.monitoring import resident_weight_bytes
    cfg = get_config("paper_tiny")
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    fp0, i80, i40 = resident_weight_bytes(params)
    assert i80 == 0 and i40 == 0 and fp0 > 0
    pq = Q.prequantize_tree(params, QW8)
    fp1, i81, i41 = resident_weight_bytes(pq)
    assert i81 > 0 and i41 == 0
    # every int8 byte replaced >= 1 byte of fp storage (fp32/bf16 params)
    assert fp0 - fp1 >= i81
