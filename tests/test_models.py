"""Per-architecture smoke tests (reduced configs) + serving-path
equivalence invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, QuantConfig, get_config, reduced
from repro.models.registry import build

QN = QuantConfig(mode="none")


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_grad(arch, rng):
    """One forward + train step on CPU: output shapes, no NaNs (assignment
    requirement for every assigned architecture)."""
    cfg = reduced(get_config(arch), dtype="float32")
    api = build(cfg)
    params = api.init_params(rng)
    batch = api.make_batch(rng, 2, 32)
    logits, _ = api.forward(params, batch, QN)
    text_len = api.text_len(32)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab_size
    assert not bool(jnp.isnan(logits).any())
    loss, aux = api.loss_fn(params, batch, QN)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda p: api.loss_fn(p, batch, QN)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ["smollm-360m", "olmoe-1b-7b",
                                  "jamba-v0.1-52b", "xlstm-350m",
                                  "whisper-base", "internvl2-26b"])
def test_prefill_decode_matches_forward(arch, rng):
    """Serving path (prefill + stepwise decode) reproduces the teacher-forced
    forward logits, including with a cushion prefix."""
    cfg = reduced(get_config(arch), dtype="float32")
    api = build(cfg)
    params = api.init_params(rng)
    batch = api.make_batch(rng, 2, 16)
    cushion = None
    if arch != "internvl2-26b":
        cushion = jax.tree_util.tree_map(
            lambda a: a * 0 + 0.03, api.cushion_zeros(4))
    full, _ = api.forward(params, batch, QN, cushion=cushion)
    text_len = batch["tokens"].shape[1]
    split = text_len // 2
    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, :split]
    cache = api.init_cache(2, 64)
    lg, cache, pos = api.prefill(params, pre_batch, cache, QN,
                                 cushion=cushion)
    offset = full.shape[1] - text_len     # vlm: patches precede text
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(full[:, offset + split - 1]),
                               rtol=5e-3, atol=5e-3)
    for i in range(split, min(split + 4, text_len)):
        lg, cache = api.decode_step(params, batch["tokens"][:, i], pos,
                                    cache, QN)
        pos = pos + 1
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full[:, offset + i]),
                                   rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("arch", ["smollm-360m", "qwen1.5-0.5b"])
def test_cushion_kv_equivalence(arch, rng):
    """Paper eq. (8): forward conditioned on the extracted prefix KV equals
    forward over the concatenated token sequence, at token positions."""
    cfg = reduced(get_config(arch), dtype="float32")
    api = build(cfg)
    params = api.init_params(rng)
    batch = api.make_batch(rng, 2, 12)
    prefix = jnp.asarray([5, 9, 3], jnp.int32)
    with_tokens, _ = api.forward_with_token_prefix(params, prefix, batch, QN)
    cushion = api.extract_cushion(params, prefix, batch, QN)
    with_kv, _ = api.forward(params, batch, QN, cushion=cushion)
    np.testing.assert_allclose(np.asarray(with_tokens[:, 3:]),
                               np.asarray(with_kv), rtol=2e-3, atol=2e-3)


def test_quantized_forward_modes(rng):
    cfg = reduced(get_config("smollm-360m"), dtype="float32")
    api = build(cfg)
    params = api.init_params(rng)
    batch = api.make_batch(rng, 2, 16)
    ref, _ = api.forward(params, batch, QN)
    for mode in ["pt_dynamic", "ptoken_dynamic"]:
        out, _ = api.forward(params, batch, QuantConfig(mode=mode))
        rel = float(jnp.abs(out - ref).max() / jnp.abs(ref).max())
        assert rel < 0.2, (mode, rel)


def test_taps_structure(rng):
    cfg = reduced(get_config("smollm-360m"), dtype="float32")
    api = build(cfg)
    params = api.init_params(rng)
    batch = api.make_batch(rng, 2, 16)
    _, taps = api.forward(params, batch, QuantConfig(mode="pt_dynamic"),
                          collect=True)
    assert "layers" in taps and "qkv" in taps["layers"]
    assert taps["layers"]["qkv"]["qerr"].shape == (cfg.n_layers,)
    assert taps["layers"]["qkv"]["absmax_ch"].shape == (cfg.n_layers,
                                                        cfg.d_model)
