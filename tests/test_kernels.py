"""Per-kernel shape/dtype sweeps, interpret-mode vs pure-jnp oracle."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as R
from repro.kernels.act_quant import act_quant_ptoken, act_quant_static
from repro.kernels.flash_attention import flash_attention
from repro.kernels.w8a8_matmul import w8a8_matmul


@pytest.mark.parametrize("M,K,N,bm,bn,bk", [
    (128, 128, 128, 128, 128, 128),
    (256, 512, 384, 128, 128, 256),
    (128, 256, 512, 64, 512, 128),
])
def test_w8a8_matmul_shapes(M, K, N, bm, bn, bk):
    rng = np.random.RandomState(M + K + N)
    x = jnp.asarray(rng.randint(-127, 128, (M, K)), jnp.int8)
    w = jnp.asarray(rng.randint(-127, 128, (K, N)), jnp.int8)
    s_x, z_x, s_w = 0.013, -5.0, 0.02
    out = w8a8_matmul(x, w, s_x, z_x, s_w, bm=bm, bn=bn, bk=bk,
                      interpret=True)
    ref = R.w8a8_matmul_ref(x, w, jnp.float32(s_x), jnp.float32(z_x),
                            jnp.float32(s_w))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("M,D", [(128, 256), (256, 960)])
def test_act_quant_static_sweep(M, D, dtype):
    rng = np.random.RandomState(M + D)
    x = jnp.asarray(rng.randn(M, D) * 4, dtype)
    s, z = 0.06, 17.0
    out = act_quant_static(x, s, z, bm=128, interpret=True)
    ref = R.act_quant_static_ref(x.astype(jnp.float32), jnp.float32(s),
                                 jnp.float32(z))
    # bf16 rounding can flip values at the .5 boundary: allow off-by-one
    diff = np.abs(np.asarray(out, np.int32) - np.asarray(ref, np.int32))
    assert diff.max() <= (0 if dtype == jnp.float32 else 1)


@pytest.mark.parametrize("M,D", [(128, 128), (256, 512)])
def test_act_quant_ptoken_sweep(M, D):
    rng = np.random.RandomState(M * D)
    x = jnp.asarray(rng.randn(M, D).astype(np.float32) * 2)
    out, s, z = act_quant_ptoken(x, bm=128, interpret=True)
    ref, rs, rz = R.act_quant_ref(x, per_token=True)
    # fp associativity at the .5 rounding boundary: allow off-by-one
    diff = np.abs(np.asarray(out, np.int32) - np.asarray(ref, np.int32))
    assert diff.max() <= 1 and (diff != 0).mean() < 1e-3
    np.testing.assert_allclose(np.asarray(s), np.asarray(rs), rtol=1e-6)


@pytest.mark.parametrize("B,H,S,T_extra,hd,prefix,causal", [
    (1, 2, 64, 0, 64, 0, True),
    (2, 3, 100, 7, 64, 7, True),     # unaligned + cushion prefix
    (1, 4, 128, 16, 128, 16, True),
    (2, 2, 96, 0, 32, 0, False),
])
def test_flash_attention_sweep(B, H, S, T_extra, hd, prefix, causal):
    rng = np.random.RandomState(S + hd)
    q = jnp.asarray(rng.randn(B, H, S, hd).astype(np.float32))
    k = jnp.asarray(rng.randn(B, H, S + T_extra, hd).astype(np.float32))
    v = jnp.asarray(rng.randn(B, H, S + T_extra, hd).astype(np.float32))
    out = flash_attention(q, k, v, causal=causal, prefix_len=prefix,
                          bq=32, bkv=64, interpret=True)
    ref = R.flash_attention_ref(q, k, v, causal=causal, prefix_len=prefix)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(1, 2, 64, 64), jnp.bfloat16)
    k = jnp.asarray(rng.randn(1, 2, 64, 64), jnp.bfloat16)
    v = jnp.asarray(rng.randn(1, 2, 64, 64), jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, bq=32, bkv=32,
                          interpret=True)
    ref = R.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_prefix_attends_fully():
    """Every query must see the cushion block: with a huge prefix value the
    output should be dominated by prefix V rows for all positions."""
    B, H, S, hd, m = 1, 1, 16, 8, 2
    q = jnp.ones((B, H, S, hd))
    k = jnp.zeros((B, H, m + S, hd)).at[:, :, :m].set(10.0)
    v = jnp.zeros((B, H, m + S, hd)).at[:, :, :m].set(1.0)
    out = flash_attention(q, k, v, causal=True, prefix_len=m, bq=8, bkv=8,
                          interpret=True)
    assert float(out.min()) > 0.95


def test_qdot_pallas_matches_int8_reference():
    from repro.configs import QuantConfig
    from repro.core import quantization as Q
    from repro.kernels.ops import qdot_pallas
    rng = np.random.RandomState(3)
    x = jnp.asarray(rng.randn(2, 37, 256).astype(np.float32))
    w = jnp.asarray(rng.randn(256, 128).astype(np.float32) * 0.1)
    qcfg = QuantConfig(mode="pt_static", true_int8=True)
    scale, zero = Q.params_from_minmax(jnp.min(x), jnp.max(x), 8, False)
    site = Q.SiteScale(scale=scale, zero=zero)
    a = qdot_pallas(x, w, qcfg, site)
    b = Q.true_int_dot(x, w, qcfg, site)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-4)


@pytest.mark.parametrize("M,bm", [(5, 32), (77, 32), (300, 128)])
def test_w8a8_matmul_ragged_m(M, bm):
    """Ragged token counts: M is padded to the tile internally and the
    output sliced back — serving batches no longer need tile-exact M."""
    rng = np.random.RandomState(M)
    x = jnp.asarray(rng.randint(-127, 128, (M, 256)), jnp.int8)
    w = jnp.asarray(rng.randint(-127, 128, (256, 128)), jnp.int8)
    s_x, z_x, s_w = 0.011, 3.0, 0.04
    out = w8a8_matmul(x, w, s_x, z_x, s_w, bm=bm, bn=128, bk=128,
                      interpret=True)
    ref = R.w8a8_matmul_ref(x, w, jnp.float32(s_x), jnp.float32(z_x),
                            jnp.float32(s_w))
    assert out.shape == (M, 128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-5)
