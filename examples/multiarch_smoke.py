"""Every assigned architecture, one reduced-config train step + one decode
step on CPU — demonstrates the uniform model API across families.

    PYTHONPATH=src python examples/multiarch_smoke.py [--arch <id>]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, QuantConfig, get_config, reduced
from repro.models.registry import build


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    args = ap.parse_args()
    archs = [args.arch] if args.arch else ARCH_IDS
    qn = QuantConfig(mode="none")
    rng = jax.random.PRNGKey(0)
    for arch in archs:
        t0 = time.time()
        cfg = reduced(get_config(arch), dtype="float32")
        api = build(cfg)
        params = api.init_params(rng)
        batch = api.make_batch(rng, 2, 32)
        loss, _ = api.loss_fn(params, batch, qn)
        # decode one token through the serving path
        cache = api.init_cache(2, 64)
        pre = dict(batch)
        pre["tokens"] = batch["tokens"][:, :8]
        lg, cache, pos = api.prefill(params, pre, cache, qn)
        tok = jnp.argmax(lg.reshape(2, -1)[:, -cfg.vocab_size:], -1)
        lg2, cache = api.decode_step(params, tok.astype(jnp.int32), pos,
                                     cache, qn)
        n = sum(x.size for x in jax.tree_util.tree_leaves(params))
        print(f"{arch:16s} loss={float(loss):6.3f} params={n:>9,} "
              f"decode_logits={tuple(lg2.shape)} ({time.time()-t0:.1f}s)")


if __name__ == "__main__":
    main()
