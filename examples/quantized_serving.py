"""Quantized serving with a CushionCache: batched prefill + decode under
per-tensor static W8A8 — the paper's deployment configuration — with
TTFT/TPOT measurement across quantization granularities.

    PYTHONPATH=src python examples/quantized_serving.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import QuantConfig, get_config
from repro.core.calibration import calibrate
from repro.data.pipeline import Pipeline, SyntheticCorpus
from repro.models.registry import build
from repro.serving.engine import Engine


def main():
    cfg = get_config("paper_tiny")
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    corpus = SyntheticCorpus(cfg.vocab_size, seed=0)
    pipe = Pipeline(corpus, batch=4, seq_len=64, seed=0)
    batch = {k: jnp.asarray(v) for k, v in pipe.get_batch(0).items()}
    calb = [{k: jnp.asarray(v) for k, v in pipe.get_batch(100 + i).items()}
            for i in range(2)]

    # a cushion straight from nonsemantic tokens (greedy-search output stand-in)
    cushion = api.extract_cushion(params, jnp.asarray([1, 2, 3], jnp.int32),
                                  None, QuantConfig(mode="none"))

    print(f"{'mode':24s} {'TTFT ms':>10s} {'TPOT ms':>10s}")
    for mode in ["none", "ptoken_dynamic", "pt_dynamic", "pt_static"]:
        qcfg = QuantConfig(mode=mode)
        scales = None
        if mode == "pt_static":
            scales, _ = calibrate(api, params, calb, qcfg, cushion=cushion)
        eng = Engine(api, params, qcfg, cushion=cushion, scales=scales,
                     max_seq=160)
        eng.generate(batch, 8)               # warm/compile
        res = eng.generate(batch, 24)
        print(f"{mode + '+cushion':24s} {res.ttft_ms:10.1f} "
              f"{res.tpot_ms:10.2f}")


if __name__ == "__main__":
    main()
