"""Quickstart: train a tiny LM, discover a CushionCache, and compare
per-tensor static W8A8 quantization with and without it.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import CushionConfig, QuantConfig, RunConfig, get_config
from repro.core import cushioncache as CC
from repro.core.calibration import calibrate
from repro.data.pipeline import Pipeline, SyntheticCorpus
from repro.models.registry import build
from repro.train.trainer import eval_ppl, make_optimizer, make_train_step


def main():
    cfg = get_config("paper_tiny")
    api = build(cfg)
    corpus = SyntheticCorpus(cfg.vocab_size, seed=0)
    pipe = Pipeline(corpus, batch=8, seq_len=128, seed=0)

    # 1. train a small model so activations have structure
    run = RunConfig(model=cfg, seq_len=128, global_batch=8, lr=2e-3,
                    train_steps=120, warmup_steps=10)
    params = api.init_params(jax.random.PRNGKey(0))
    opt = make_optimizer(run)
    st = opt.init(params)
    step = jax.jit(make_train_step(api, run, opt))
    for i in range(run.train_steps):
        b = {k: jnp.asarray(v) for k, v in pipe.get_batch(i).items()}
        params, st, m = step(params, st, b)
        if i % 40 == 0:
            print(f"step {i}: loss {float(m['loss']):.3f}")

    evalb = [{k: jnp.asarray(v) for k, v in pipe.get_batch(9000 + i).items()}
             for i in range(4)]
    calb = [{k: jnp.asarray(v) for k, v in pipe.get_batch(8000 + i).items()}
            for i in range(4)]

    # 2. baseline: FP vs per-tensor static W8A8
    qn, qs = QuantConfig(mode="none"), QuantConfig(mode="pt_static")
    scales, _ = calibrate(api, params, calb, qs)
    print(f"FP ppl:            {eval_ppl(api, params, evalb, qn):.3f}")
    print(f"W8A8 static ppl:   "
          f"{eval_ppl(api, params, evalb, qs, scales=scales):.3f}")

    # 3. CushionCache: greedy search + quantization-aware prefix tuning
    ccfg = CushionConfig(max_prefix_len=4, tau=0.98, n_candidates=32,
                         tune_steps=40, seed_tokens=(1,))
    def sample_fn(i):
        b = pipe.get_batch(5000 + i)
        return {"tokens": jnp.asarray(b["tokens"][:1]),
                "labels": jnp.asarray(b["labels"][:1])}
    def tune_iter():
        i = 0
        while True:
            b = pipe.get_batch(6000 + i)
            yield {k: jnp.asarray(v) for k, v in b.items()}
            i += 1
    cushion, sr, tr = CC.discover(api, params, sample_fn, tune_iter(),
                                  QuantConfig(mode="pt_dynamic"), ccfg,
                                  jax.random.PRNGKey(1), verbose=True)
    print(f"prefix tokens: {sr.prefix_ids.tolist()}")

    # 4. quantize WITH the cushion (recalibrate for the deployment config)
    cscales, _ = calibrate(api, params, calb, qs, cushion=cushion)
    ppl_cc = eval_ppl(api, params, evalb, qs, cushion=cushion,
                      scales=cscales)
    print(f"W8A8 static + CushionCache ppl: {ppl_cc:.3f}")


if __name__ == "__main__":
    main()
